"""Pipeline parallelism: GPipe-style collective pipeline over a mesh axis.

The layer stack (already stacked with a leading repeat dim) is split into
``n_stages`` groups laid out along a ``stage`` mesh axis.  Inside
``shard_map``, every stage holds its parameter shard; microbatches stream
through via ``lax.ppermute`` rotations: at step t, stage s computes
microbatch (t - s) — the classic skew — so after a fill of (S-1) steps all
stages run concurrently.  Forward-only (serving / prefill pipelines);
training composes this with grad accumulation outside.

This realizes the PP letter of DP/TP/PP/EP/SP on the same mesh fabric the
redistribution core addresses: stage boundaries are just another
distributed-layout transition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh as JMesh, NamedSharding, PartitionSpec as P


def split_stages(stacked_params, n_stages: int):
    """Re-stack (L, ...) layer params as (n_stages, L/n_stages, ...)."""
    def resplit(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(resplit, stacked_params)


def pipeline_forward(stage_params, x_microbatches, apply_layer, *,
                     mesh: JMesh, stage_axis: str = "stage"):
    """Run microbatches through the pipeline.

    stage_params: pytree with leading (n_stages, layers_per_stage, ...),
        sharded so each stage holds its slice (P(stage_axis) on dim 0).
    x_microbatches: (n_micro, mb, ...) activations (replicated).
    apply_layer: (layer_params, x) -> x.
    Returns (n_micro, mb, ...) outputs.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_microbatches.shape[0]
    steps = n_micro + n_stages - 1

    def stage_fn(params, xs):
        # params: (1, layers_per_stage, ...) local; xs: (n_micro, mb, ...)
        sid = jax.lax.axis_index(stage_axis)
        local = jax.tree.map(lambda v: v[0], params)

        def run_stage(x):
            def body(h, lp):
                return apply_layer(lp, h), None
            h, _ = jax.lax.scan(body, x, local)
            return h

        out = jnp.zeros_like(xs)
        carry = jnp.zeros_like(xs[0])

        def step(t, state):
            carry, out = state
            # stage 0 ingests microbatch t; others use the rotated carry
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jax.lax.dynamic_index_in_dim(xs, mb_idx, keepdims=False)
            h = jnp.where(sid == 0, inject, carry)
            active = (t - sid >= 0) & (t - sid < n_micro)
            h = jnp.where(active, run_stage(h), h)
            # last stage emits microbatch (t - n_stages + 1)
            emit_idx = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            do_emit = active & (sid == n_stages - 1)
            out = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h, emit_idx, axis=0),
                lambda o: o, out)
            # rotate activations to the next stage
            carry = jax.lax.ppermute(
                h, stage_axis,
                perm=[(i, (i + 1) % n_stages) for i in range(n_stages)])
            return carry, out

        _, out = jax.lax.fori_loop(0, steps, step, (carry, out))
        # the final outputs live on the last stage; broadcast them
        out = jax.lax.psum(
            jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out)),
            stage_axis)
        return out

    fn = jax.shard_map(
        stage_fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(stage_axis), stage_params),
                  P()),
        out_specs=P(), check_vma=False)
    return fn(stage_params, x_microbatches)
