"""Sharding policies: parameter/optimizer/activation PartitionSpec trees.

Policies compose:
  dp    — replicate params, shard batch over data axes.
  tp    — Megatron tensor parallelism over the "model" axis (attention
          heads / FFN hidden / vocab); EP for MoE experts.
  fsdp  — additionally shard the largest remaining parameter dim over the
          data axes (params gathered per layer by XLA).
  zero1 — optimizer moments sharded over data axes even when params are
          only TP-sharded.

Specs are *hints* under pjit/GSPMD: any assignment is semantics-preserving,
XLA inserts the collectives — which is exactly the setting the paper's
redistribution synthesis optimizes.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.tree_util import tree_map_with_path, DictKey, SequenceKey

from repro.models.config import ModelConfig

MODEL = "model"


def _path_names(path):
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(str(k.idx))
    return out


# parameter-name -> (dim sharded by TP) for 2D kernels (without any leading
# stacked/expert dims).  +1 = output dim, 0 = input dim.
_TP_OUT = {"wq", "wk", "wv", "wi", "wg", "wx", "wy", "wq_b", "wkv_b", "wup",
           "w_input_gate", "w_rec_gate"}
_TP_IN = {"wo"}
_REPLICATE = {"router", "wq_a", "wkv_a", "wf", "frontend_proj", "conv", "r",
              "b", "scale", "lam"}


def param_specs(params, cfg: ModelConfig, *, data_axes: tuple[str, ...],
                policy: str = "tp") -> object:
    """PartitionSpec tree matching the param tree."""
    use_tp = policy in ("tp", "fsdp", "fsdp+tp", "fsdp_etp")
    use_fsdp = policy.startswith("fsdp")
    etp = policy == "fsdp_etp"
    data = tuple(data_axes)

    def spec_for(path, leaf):
        names = _path_names(path)
        shape = np.shape(leaf)
        nd = len(shape)
        stacked = "blocks" in names          # leading layer-repeat dim
        lead = 1 if stacked else 0
        entry = [None] * nd
        pname = None
        for n in reversed(names):
            if n not in ("w",):
                pname = n
                break
        if nd - lead == 0 or nd - lead == 1:
            return P(*entry)

        def place(i, axes):
            """Shard dim i over axes iff free and divisible."""
            prod = int(np.prod([_axis_size(a) for a in
                                ((axes,) if isinstance(axes, str) else axes)]))
            if entry[i] is None and shape[i] % prod == 0 and shape[i] >= prod:
                entry[i] = axes
                return True
            return False

        is_expert = pname in ("wi", "wg", "wo") and nd - lead == 3
        if use_tp:
            if is_expert and etp:
                # EP over model + tensor-parallel F over data: expert
                # weights are never gathered; only activations move.
                place(lead, MODEL) or place(nd - 1, MODEL)
                fdim = nd - 1 if pname in ("wi", "wg") else lead + 1
                daxes = data if len(data) > 1 else data[0]
                place(fdim, daxes)
                return P(*entry)   # exempt from generic FSDP below
            elif is_expert:
                # EP over model; if E doesn't divide, shard the FFN dim
                place(lead, MODEL) or place(nd - 1, MODEL)
            elif pname == "embed" or (len(names) >= 2
                                      and names[-2] == "embed"):
                place(lead, MODEL) or place(lead + 1, MODEL)
            elif pname == "lm_head" or (len(names) >= 2
                                        and names[-2] == "lm_head"):
                place(lead + 1, MODEL) or place(lead, MODEL)
            elif pname in _TP_OUT:
                place(nd - 1, MODEL) or place(lead, MODEL)
            elif pname in _TP_IN:
                place(lead, MODEL) or place(nd - 1, MODEL)
        if use_fsdp:
            # shard the largest still-unsharded dim over the data axes
            daxes = data if len(data) > 1 else data[0]
            cand = [i for i in range(lead, nd) if entry[i] is None
                    and shape[i] % int(np.prod([_axis_size(a) for a in data])
                                       ) == 0]
            if cand:
                big = max(cand, key=lambda i: shape[i])
                entry[big] = daxes
        return P(*entry)

    return tree_map_with_path(spec_for, params)


_AXIS_SIZES: dict[str, int] = {}


def _axis_size(a: str) -> int:
    return _AXIS_SIZES.get(a, 1)


def set_axis_sizes(sizes: dict[str, int]):
    _AXIS_SIZES.clear()
    _AXIS_SIZES.update(sizes)


def opt_state_specs(params, pspecs, *, data_axes: tuple[str, ...],
                    zero1: bool = True):
    """Moments mirror the params' specs; ZeRO-1 additionally shards
    moments of data-replicated params over the data axes (largest
    divisible dim), cutting optimizer memory by the DP degree."""
    data = tuple(data_axes)
    n_data = int(np.prod([_axis_size(a) for a in data_axes]))

    def moment_spec(p, spec):
        ent = list(spec) if len(spec) else [None] * np.ndim(p)
        while len(ent) < np.ndim(p):
            ent.append(None)
        if zero1 and not any(e in (data, data_axes[0]) or
                             (isinstance(e, tuple) and set(e) & set(data))
                             for e in ent if e):
            shape = np.shape(p)
            cand = [i for i in range(len(ent)) if ent[i] is None
                    and shape[i] % n_data == 0]
            if cand:
                big = max(cand, key=lambda i: shape[i])
                ent[big] = data if len(data) > 1 else data[0]
        return P(*ent)

    mspec = jax.tree.map(moment_spec, params, pspecs)
    return {"mu": mspec, "nu": jax.tree.map(lambda s: s, mspec),
            "step": P()}


def batch_specs(cfg: ModelConfig, data_axes: tuple[str, ...]):
    d = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    tok = P(d, None, None) if cfg.n_codebooks > 1 else P(d, None)
    specs = {"tokens": tok, "labels": tok}
    if cfg.frontend:
        specs["frontend_embeds"] = P(d, None, None)
    return specs


def cache_specs(cache, data_axes: tuple[str, ...], batch_size: int,
                seq_shard: bool = False):
    """KV/state caches: batch over data when divisible, else heads/width
    over model; leading dim is the layer stack.  ``seq_shard=True``
    additionally shards the cache length dim over the model axis
    (sequence-parallel KV — decode attention reduces partial softmax
    across model shards instead of replicating the cache)."""
    d = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    n_data = int(np.prod([_axis_size(a) for a in data_axes]))

    def spec_for(path, leaf):
        shape = np.shape(leaf)
        nd = len(shape)
        entry = [None] * nd
        # leaf layout: (layer_stack, B, ...)
        if nd >= 2 and shape[1] == batch_size and batch_size % n_data == 0:
            entry[1] = d
            if seq_shard and nd >= 4 and shape[2] % _axis_size(MODEL) == 0:
                entry[2] = MODEL   # (stack, B, L, ...) length dim
        elif nd >= 3:
            # long-context single-sequence decode: shard the largest
            # non-batch dim over model (sequence/width parallelism)
            cand = [i for i in range(2, nd)
                    if shape[i] % _axis_size(MODEL) == 0]
            if cand:
                big = max(cand, key=lambda i: shape[i])
                entry[big] = MODEL
        return P(*entry)

    return tree_map_with_path(spec_for, cache)
