"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-leaf symmetric quantization applied to gradients before the
data-parallel reduction, with an error-feedback accumulator so the bias is
re-injected next step (1-bit/8-bit SGD literature).  On TPU this shrinks
the DP all-reduce bytes 4x (fp32) / 2x (bf16); numerically validated in
tests/test_optim.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def compress_decompress(g, err):
    """Returns (dequantized gradient, new error) — simulates the int8
    all-reduce payload; the reduction is linear so quantize-then-reduce
    equals reduce-of-quantized in expectation."""
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def apply(grads, err_state):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    pairs = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    err = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return deq, err
