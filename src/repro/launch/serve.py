"""Serving entry point (continuous batching).

  python -m repro.launch.serve --arch qwen2_0_5b --reduced --requests 6
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_len=args.max_len)
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        r = Request(rid=i, prompt=rng.randint(0, cfg.vocab, size=3 + i % 5),
                    max_new_tokens=args.max_new)
        reqs.append(r)
        eng.submit(r)
    steps = eng.run_until_drained()
    print(f"[{cfg.name}] drained {len(reqs)} requests on {args.slots} slots "
          f"in {steps} engine steps")
    for r in reqs[:3]:
        print(f"  rid={r.rid} prompt={r.prompt.tolist()} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
