"""Training entry point.

  python -m repro.launch.train --arch qwen2_0_5b [--reduced] --steps 50

Full configs are intended for the TPU pods the dry-run proves out;
``--reduced`` runs the same code path at smoke scale on CPU.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    def report(step, m):
        if step % 5 == 0:
            print(f"[{cfg.name}] step {step:4d} loss {float(m['loss']):.4f} "
                  f"({m['step_time'] * 1e3:.0f} ms)", flush=True)

    res = train(cfg,
                TrainConfig(steps=args.steps, microbatches=args.microbatches,
                            ckpt_dir=args.ckpt,
                            grad_compression=args.compress),
                DataConfig(global_batch=args.batch, seq_len=args.seq),
                AdamWConfig(lr=args.lr, warmup_steps=5,
                            total_steps=args.steps),
                on_metrics=report)
    print(f"final loss {res.losses[-1]:.4f} "
          f"(from {res.losses[0]:.4f}); stragglers: {len(res.stragglers)}")


if __name__ == "__main__":
    main()
