"""HLO text analysis: collective operand bytes + op census for §Roofline.

``cost_analysis()`` has no collective traffic, so we parse the compiled
module: sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import re
from collections import Counter

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo: str) -> dict:
    """Per-collective-kind total output bytes (per device, since HLO shapes
    in SPMD modules are per-partition).  Handles tuple-shaped results
    (e.g. multi-operand all-to-all) and async -start/-done pairs."""
    out: Counter = Counter()
    count: Counter = Counter()
    for m in _OP_RE.finditer(hlo):
        lhs, kind = m.group(1), m.group(2)
        nbytes = sum(shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(lhs))
        out[kind] += nbytes
        count[kind] += 1
    return {"bytes": dict(out), "count": dict(count),
            "total_bytes": int(sum(out.values()))}


def count_ops(hlo: str) -> dict:
    """Census of expensive op kinds (fusion/dot/collectives)."""
    census: Counter = Counter()
    for kind in ("fusion", "dot", "convolution", "custom-call",
                 *_COLLECTIVES):
        census[kind] = len(re.findall(rf"\s{kind}[.(\s]", hlo))
    return dict(census)
