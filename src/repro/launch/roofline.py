"""§Roofline: three-term roofline per (arch × shape) from compiled dry-runs.

    compute    = HLO_FLOPs / (chips × 197 TF/s)         [bf16 v5e]
    memory     = HLO_bytes / (chips × 819 GB/s)
    collective = collective_bytes / (chips × 50 GB/s)

HLO metrics from ``compiled.cost_analysis()`` + HLO-text collective sums.
Because XLA counts while-loop bodies ONCE (independent of trip count),
the layer-scan contribution is reconstructed from two probe compiles with
the layer loop UNROLLED (L = pattern_len and 2·pattern_len):
    body  = m_unrolled(2p) − m_unrolled(p);   outer = m_unrolled(p) − body
    corrected = outer + (repeats + remainder/pattern_len) · body
(sLSTM's inner sequence scan is additionally corrected analytically —
its recurrent matmul is invisible to HLO costing at any layer count.)

Note on units: the compiled module is the per-partition SPMD program, so
cost_analysis flops/bytes are already per-chip; no further division.
"""
from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

from repro.core.costmodel import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.configs.registry import SHAPES, get_config

HW = {"peak_flops": PEAK_FLOPS_BF16, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}


def _metric(r, name):
    if name == "collective":
        return float(r["collective_bytes"]["total_bytes"])
    return float(r[name])


def _slstm_extra_flops(cfg, shape, n_dev) -> float:
    """Per-device flops of sLSTM inner-scan recurrent matmuls (invisible
    to HLO costing: while-in-while).  4 gates × block-diag R (H, hd, hd),
    2 flops/MAC, per token."""
    n_slstm = sum(1 for (sq, _) in (cfg.pattern * cfg.pattern_repeats +
                                    cfg.remainder) if sq == "slstm")
    if not n_slstm:
        return 0.0
    hd = cfg.d_model // cfg.n_heads
    per_tok = 4 * cfg.n_heads * hd * hd * 2
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 3  # fwd + bwd
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 1
    else:
        tokens = shape.global_batch
        mult = 1
    return n_slstm * per_tok * tokens * mult / n_dev


def corrected_metrics(cell: dict, probe1: dict, probe2: dict) -> dict:
    p = cell["pattern_len"]
    reps_eff = cell["pattern_repeats"] + cell["remainder_len"] / p
    out = {}
    for m in ("flops", "bytes_accessed", "collective"):
        m1, m2 = _metric(probe1, m), _metric(probe2, m)
        body = max(m2 - m1, 0.0)
        outer = max(m1 - body, 0.0)
        corrected = outer + reps_eff * body
        out[m] = {"raw": _metric(cell, m), "body": body, "outer": outer,
                  "corrected": corrected}
    return out


def model_flops(cfg, shape, n_dev) -> float:
    """Task-spec MODEL_FLOPS: 6·N·D (train) / 2·N_active·D (inference),
    per device."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * n * tokens / n_dev
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * n * tokens / n_dev
    return 2 * n * shape.global_batch / n_dev


def analyze(dirpath="experiments/dryrun", mesh="singlepod") -> list[dict]:
    rows = []
    d = Path(dirpath)
    for f in sorted(d.glob(f"*.{mesh}.json")):
        cell = json.loads(f.read_text())
        if cell.get("status") != "ok":
            if cell.get("status") == "skipped":
                rows.append({"arch": cell["arch"], "shape": cell["shape"],
                             "status": "skipped",
                             "reason": cell.get("reason", "")})
            continue
        arch, shape_name = cell["arch"], cell["shape"]
        p = cell["pattern_len"]
        pol = cell["policy"]
        p1 = d / f"{arch}.{shape_name}.{mesh}.{pol}.L{p}.U.json"
        p2 = d / f"{arch}.{shape_name}.{mesh}.{pol}.L{2 * p}.U.json"
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        n_dev = cell["n_devices"]
        if p1.exists() and p2.exists():
            probe1 = json.loads(p1.read_text())
            probe2 = json.loads(p2.read_text())
            if probe1.get("status") == "ok" and probe2.get("status") == "ok":
                mets = corrected_metrics(cell, probe1, probe2)
            else:
                mets = {m: {"raw": _metric(cell, m),
                            "corrected": _metric(cell, m)}
                        for m in ("flops", "bytes_accessed", "collective")}
        else:
            mets = {m: {"raw": _metric(cell, m),
                        "corrected": _metric(cell, m)}
                    for m in ("flops", "bytes_accessed", "collective")}
        flops = mets["flops"]["corrected"] + _slstm_extra_flops(
            cfg, shape, n_dev)
        byts = mets["bytes_accessed"]["corrected"]
        coll = mets["collective"]["corrected"]

        t_comp = flops / HW["peak_flops"]
        t_mem = byts / HW["hbm_bw"]
        t_coll = coll / HW["ici_bw"]
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        mf = model_flops(cfg, shape, n_dev)
        rows.append({
            "arch": arch, "shape": shape_name, "status": "ok",
            "policy": pol, "n_devices": n_dev,
            "flops": flops, "bytes": byts, "collective_bytes": coll,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "bottleneck": dom,
            # fraction of bf16 peak this step achieves when running at its
            # limiting roofline term (an MFU upper bound for the config)
            "roofline_fraction": (mf / HW["peak_flops"])
                                 / max(max(terms.values()), 1e-30),
            "model_flops": mf,
            "useful_ratio": mf / max(flops, 1e-30),
            "raw_flops": mets["flops"]["raw"],
            "temp_bytes": cell.get("temp_size_in_bytes"),
            "arg_bytes": cell.get("argument_size_in_bytes"),
        })
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | policy | compute(s) | memory(s) | coll.(s) | "
           "bottleneck | roofline | useful | temp/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        tb = r.get("temp_bytes")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['policy']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['bottleneck']}** "
            f"| {r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} "
            f"| {tb / 1e9:.1f}GB |" if tb else
            f"| {r['arch']} | {r['shape']} | {r['policy']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['bottleneck']}** "
            f"| {r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} "
            f"| n/a |")
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()
    rows = analyze(args.dir)
    Path(args.out).write_text(json.dumps(rows, indent=2))
    print(to_markdown(rows))


if __name__ == "__main__":
    main()
