"""Step functions + abstract inputs for training / prefill / decode.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — consumed by the
dry-run's .lower(); the same builders drive the real trainer/server.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeSpec, get_config
from repro.models import decode_step, init_cache, init_params, loss_fn
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.sharding import policies


def arch_policy(cfg: ModelConfig) -> str:
    """Default parallelism policy per architecture size/family."""
    if cfg.param_count() > 3e10:
        return "fsdp"        # giants: FSDP(+EP over model)
    return "tp"


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.PRNGKey(0))


def abstract_opt_state(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_state(abstract_params(cfg)))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len))


def batch_structs(cfg: ModelConfig, batch: int, seq: int):
    tokshape = (batch, seq, cfg.n_codebooks) if cfg.n_codebooks > 1 \
        else (batch, seq)
    out = {"tokens": jax.ShapeDtypeStruct(tokshape, jnp.int32),
           "labels": jax.ShapeDtypeStruct(tokshape, jnp.int32)}
    if cfg.frontend:
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype))
    return out


def input_specs(arch_or_cfg, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for one (arch, shape) cell."""
    cfg = arch_or_cfg if isinstance(arch_or_cfg, ModelConfig) \
        else get_config(arch_or_cfg)
    if shape.kind == "train":
        return {"batch": batch_structs(cfg, shape.global_batch, shape.seq_len)}
    if shape.kind == "prefill":
        return {"batch": batch_structs(cfg, shape.global_batch, shape.seq_len)}
    # decode: one new token against a cache of seq_len
    tokshape = (shape.global_batch, 1, cfg.n_codebooks) \
        if cfg.n_codebooks > 1 else (shape.global_batch, 1)
    return {
        "tokens": jax.ShapeDtypeStruct(tokshape, jnp.int32),
        "cache": abstract_cache(cfg, shape.global_batch, shape.seq_len),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                     *, remat: bool = True, unroll: bool = False):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, remat=remat,
                                   unroll=unroll)
        params, opt_state, om = apply_updates(params, grads, opt_state,
                                              opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def build_prefill_step(cfg: ModelConfig, *, unroll: bool = False):
    from repro.models import forward

    def prefill_step(params, batch):
        logits, _ = forward(params, batch, cfg, remat=False, unroll=unroll)
        return logits

    return prefill_step


def build_decode_fn(cfg: ModelConfig, *, unroll: bool = False):
    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg, unroll=unroll)

    return serve_step


# ---------------------------------------------------------------------------
# Sharding assembly for a mesh
# ---------------------------------------------------------------------------


def shardings_for(cfg: ModelConfig, mesh, shape: ShapeSpec,
                  policy: str | None = None, *, unroll: bool = False,
                  seq_shard_cache: bool = False):
    """Returns (in_shardings, out_shardings, step_fn, args) fully wired for
    jit.lower on the given mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import data_axes_of, mesh_axis_sizes

    policy = policy or arch_policy(cfg)
    policies.set_axis_sizes(mesh_axis_sizes(mesh))
    data_axes = data_axes_of(mesh)

    def ns(spec):
        return NamedSharding(mesh, spec)

    params = abstract_params(cfg)
    pspecs = policies.param_specs(params, cfg, data_axes=data_axes,
                                  policy=policy)
    if shape.kind == "train":
        opt = abstract_opt_state(cfg)
        ospecs = policies.opt_state_specs(params, pspecs,
                                          data_axes=data_axes)
        bspecs = policies.batch_specs(cfg, data_axes)
        step = build_train_step(cfg, unroll=unroll)
        args = (params, opt, input_specs(cfg, shape)["batch"])
        in_sh = (jax.tree.map(ns, pspecs), jax.tree.map(ns, ospecs),
                 jax.tree.map(ns, bspecs))
        out_sh = (jax.tree.map(ns, pspecs), jax.tree.map(ns, ospecs),
                  None)
        return in_sh, out_sh, step, args

    sizes = mesh_axis_sizes(mesh)
    vocab_ax = "model" if cfg.vocab % sizes.get("model", 1) == 0 else None
    if shape.kind == "prefill":
        bspecs = policies.batch_specs(cfg, data_axes)
        bspecs = {k: v for k, v in bspecs.items() if k != "labels"}
        step = build_prefill_step(cfg, unroll=unroll)
        batch = {k: v for k, v in input_specs(cfg, shape)["batch"].items()
                 if k != "labels"}
        args = (params, batch)
        in_sh = (jax.tree.map(ns, pspecs), jax.tree.map(ns, bspecs))
        # logits: batch over data, vocab over model (when divisible)
        d = data_axes if len(data_axes) > 1 else data_axes[0]
        out_sh = ns(P(d, None, vocab_ax))
        return in_sh, out_sh, step, args

    # decode
    spec_in = input_specs(cfg, shape)
    cspecs = policies.cache_specs(spec_in["cache"], data_axes,
                                  shape.global_batch,
                                  seq_shard=seq_shard_cache)
    step = build_decode_fn(cfg, unroll=unroll)
    n_data = 1
    for a in data_axes:
        n_data *= mesh_axis_sizes(mesh)[a]
    d = data_axes if len(data_axes) > 1 else data_axes[0]
    tok_spec = P(d, None) if shape.global_batch % n_data == 0 else P(None, None)
    if cfg.n_codebooks > 1:
        tok_spec = P(*tok_spec, None)
    args = (params, spec_in["cache"], spec_in["tokens"], spec_in["pos"])
    in_sh = (jax.tree.map(ns, pspecs), jax.tree.map(ns, cspecs),
             ns(tok_spec), ns(P()))
    logits_spec = P(tok_spec[0], None, vocab_ax)
    out_sh = (ns(logits_spec), jax.tree.map(ns, cspecs))
    return in_sh, out_sh, step, args
