"""Production meshes.

Single pod: (16, 16)        axes ("data", "model")   = 256 chips (TPU v5e pod)
Multi-pod:  (2, 16, 16)     axes ("pod", "data", "model") = 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False,
                         model_parallel: int = 16):
    """256 chips/pod; ``model_parallel`` re-splits the pod between the
    data and model axes (head-alignment hillclimb: e.g. 8 for archs whose
    head counts don't divide 16 — see EXPERIMENTS.md §Perf)."""
    import jax
    dp = 256 // model_parallel
    shape = (2, dp, model_parallel) if multi_pod else (dp, model_parallel)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    import jax
    return jax.make_mesh((1, 1), ("data", "model"))
