import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:
  * ``.lower(**ShapeDtypeStructs)`` + ``.compile()`` must succeed,
  * ``memory_analysis()`` proves the cell fits,
  * ``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
  python -m repro.launch.dryrun --arch olmo_1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out results_dir
"""
import argparse
import json
import math
import re
import sys
import time
import traceback
from pathlib import Path


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy: str | None = None, save_hlo: str | None = None,
             remat: bool = True, layers: int | None = None,
             unroll: bool = False, variant: str = ""):
    import jax

    from repro.configs.registry import SHAPES, applicable, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import shardings_for
    from repro.launch.hlo_analysis import collective_bytes, count_ops

    ok, reason = applicable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}

    cfg = get_config(arch)
    from repro.launch.steps import arch_policy
    policy = policy or arch_policy(cfg)   # pin BEFORE any layer override
    import dataclasses
    seq_shard_cache = False
    model_parallel = 16
    for v in filter(None, variant.split("+")):
        if v.startswith("attnchunk"):
            cfg = dataclasses.replace(cfg, attn_chunk=int(v[len("attnchunk"):]))
        elif v == "etp":
            policy = "fsdp_etp"
        elif v == "seqkv":
            seq_shard_cache = True
        elif v == "noremat":
            remat = False
        elif v == "moeconst":
            from repro.models import mlp
            mlp.set_moe_constraints(("data",), "model")
        elif v.startswith("model"):
            model_parallel = int(v[len("model"):])
        else:
            raise ValueError(f"unknown variant {v!r}")
    if layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=layers)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod,
                                model_parallel=model_parallel)
    t0 = time.time()
    in_sh, out_sh, step, args = shardings_for(cfg, mesh, shape, policy,
                                               unroll=unroll,
                                               seq_shard_cache=seq_shard_cache)
    if shape.kind == "train" and not remat:
        from repro.launch.steps import build_train_step
        step = build_train_step(cfg, remat=False, unroll=unroll)

    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.devices.size

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "policy": policy,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0) if cost else 0.0,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else 0.0,
        "collective_bytes": coll,
        "hlo_ops": count_ops(hlo),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "layers_override": layers,
        "unrolled": unroll,
        "variant": variant,
        "n_layers": cfg.n_layers,
        "pattern_len": len(cfg.pattern),
        "pattern_repeats": cfg.pattern_repeats,
        "remainder_len": len(cfg.remainder),
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                result[k] = int(v)
    if save_hlo:
        Path(save_hlo).write_text(hlo)
        result["hlo_path"] = save_hlo
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        from repro.configs.registry import all_cells
        cells = list(all_cells())
    else:
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        tag = f"{arch}.{shape}.{'multipod' if args.multi_pod else 'singlepod'}"
        if args.policy:
            tag += f".{args.policy}"
        if args.no_remat:
            tag += ".noremat"
        if args.layers is not None:
            tag += f".L{args.layers}"
        if args.unroll:
            tag += ".U"
        if args.variant:
            tag += f".V_{args.variant}"
        out_path = outdir / f"{tag}.json"
        if out_path.exists():
            print(f"[dryrun] {tag}: cached", flush=True)
            continue
        print(f"[dryrun] {tag}: lowering...", flush=True)
        try:
            res = run_cell(arch, shape, args.multi_pod, args.policy,
                           args.save_hlo, remat=not args.no_remat,
                           layers=args.layers, unroll=args.unroll,
                           variant=args.variant)
        except Exception as e:  # record failures as results: they are bugs
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
        out_path.write_text(json.dumps(res, indent=2))
        status = res.get("status")
        extra = (f" compile={res.get('compile_s')}s"
                 f" flops={res.get('flops', 0):.3g}" if status == "ok" else
                 res.get("reason", res.get("error", ""))[:120])
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
