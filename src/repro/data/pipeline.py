"""Deterministic synthetic data pipeline.

Shard-aware: every (step, data-shard) pair maps to an independent counter
-based PRNG stream, so any host can regenerate exactly its shard for any
step — which is what makes checkpoint/restart and elastic re-scaling
deterministic end-to-end (a restart at step k reproduces the batch at
step k bit-for-bit, for any new data-parallel degree that divides the
global batch).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    global_batch: int = 8
    seq_len: int = 128


class SyntheticLM:
    """Markov-ish synthetic token stream with learnable structure (the
    next token depends on the previous one), so smoke-training shows a
    decreasing loss rather than noise."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def _tokens(self, step: int, row: int, n: int) -> np.ndarray:
        rng = np.random.Generator(np.random.Philox(
            key=self.data.seed, counter=[step, row, 0, 0]))
        V = self.cfg.vocab
        toks = np.empty(n, dtype=np.int32)
        toks[0] = rng.integers(0, V)
        noise = rng.integers(0, V, size=n)
        mix = rng.random(n)
        for t in range(1, n):
            # structured: often the affine successor of the previous token
            toks[t] = (toks[t - 1] * 31 + 7) % V if mix[t] < 0.8 else noise[t]
        return toks

    def global_batch(self, step: int) -> dict:
        B, S = self.data.global_batch, self.data.seq_len
        shape = (B, S + 1)
        toks = np.stack([self._tokens(step, r, S + 1) for r in range(B)])
        batch = {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}
        if self.cfg.n_codebooks > 1:
            batch = {k: np.repeat(v[..., None], self.cfg.n_codebooks, -1)
                     for k, v in batch.items()}
        if self.cfg.frontend:
            rng = np.random.Generator(np.random.Philox(
                key=self.data.seed + 1, counter=[step, 0, 0, 0]))
            batch["frontend_embeds"] = rng.standard_normal(
                (B, self.cfg.frontend_len, self.cfg.d_model),
                dtype=np.float32) * 0.02
        return batch

    def shard_batch(self, step: int, shard: int, n_shards: int) -> dict:
        """Rows [shard * B/n : (shard+1) * B/n) of the global batch."""
        B = self.data.global_batch
        assert B % n_shards == 0
        per = B // n_shards
        rows = range(shard * per, (shard + 1) * per)
        S = self.data.seq_len
        toks = np.stack([self._tokens(step, r, S + 1) for r in rows])
        batch = {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}
        if self.cfg.n_codebooks > 1:
            batch = {k: np.repeat(v[..., None], self.cfg.n_codebooks, -1)
                     for k, v in batch.items()}
        return batch
