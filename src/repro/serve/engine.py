"""Batched serving engine: prefill + decode with per-slot request state.

Continuous batching over a fixed pool of batch slots: requests enter a
waiting queue, are prefilled into their slot's cache rows (per-slot
positions — other slots are frozen via the ``active`` row mask), and
decode steps advance every active slot together.  Prefill/decode are the
same ``forward``/``decode_step`` the dry-run lowers at production shapes.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new_tokens: int = 8
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, batch_slots, max_len)
        self.active: dict[int, Request] = {}
        self.pos = np.zeros(batch_slots, dtype=np.int32)
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(
            lambda p, c, t, pos, act: decode_step(p, c, t, pos, cfg,
                                                  active=act))

    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slot(self):
        for s in range(self.slots):
            if s not in self.active:
                return s
        return None

    def _step_rows(self, tok_b, rows):
        """One decode step advancing only ``rows`` (active mask)."""
        act = np.zeros(self.slots, dtype=bool)
        act[list(rows)] = True
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tok_b),
            jnp.asarray(self.pos), jnp.asarray(act))
        return logits

    def _prefill(self, slot: int, req: Request):
        """Prefill this slot's rows token by token (other slots frozen)."""
        self.pos[slot] = 0
        for tok in req.prompt:
            tok_b = np.zeros((self.slots, 1), np.int32)
            tok_b[slot, 0] = tok
            logits = self._step_rows(tok_b, [slot])
            self.pos[slot] += 1
        req.out_tokens.append(int(np.asarray(logits[slot, 0]).argmax()))

    def step(self):
        """One engine step: admit waiting requests, advance all decodes."""
        while self.queue and (slot := self._free_slot()) is not None:
            req = self.queue.popleft()
            self.active[slot] = req
            self._prefill(slot, req)
        if not self.active:
            return False
        tok_b = np.zeros((self.slots, 1), np.int32)
        for s, req in self.active.items():
            tok_b[s, 0] = req.out_tokens[-1]
        logits = self._step_rows(tok_b, list(self.active))
        done = []
        for s, req in list(self.active.items()):
            nxt = int(np.asarray(logits[s, 0]).argmax())
            req.out_tokens.append(nxt)
            self.pos[s] += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                done.append(s)
        for s in done:
            del self.active[s]
        return True

    def run_until_drained(self, max_steps: int = 1000):
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return steps
