"""Distributed trainer: microbatched grad accumulation, checkpoint/restart,
straggler watchdog, optional gradient compression.

Fault-tolerance model (designed for 1000+ nodes, exercised here on CPU):
  * checkpoint every N steps (async write) + restore-on-start — a failed
    node restarts the job from the latest step; the deterministic data
    pipeline replays the exact batch stream;
  * elastic restarts onto a different mesh go through
    repro.checkpoint.elastic (redistribution plans from the paper's core);
  * a step-time watchdog flags straggler steps (> k× EMA); on a real
    fleet the callback triggers hot-spare promotion — here it feeds
    metrics so the policy is testable.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params, loss_fn
from repro.models.config import ModelConfig
from repro.optim import compress
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.checkpoint import ckpt


@dataclasses.dataclass
class TrainConfig:
    steps: int = 20
    microbatches: int = 1            # gradient accumulation
    ckpt_every: int = 10
    ckpt_dir: str | None = None
    async_ckpt: bool = True
    grad_compression: bool = False
    straggler_factor: float = 3.0    # step > k * EMA => straggler
    seed: int = 0
    remat: bool = True


@dataclasses.dataclass
class TrainResult:
    losses: list
    restored_from: int | None
    stragglers: list
    steps_run: int


def build_accum_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                     tcfg: TrainConfig):
    """Microbatched train step: grads averaged over `microbatches` chunks
    of the per-step batch (re-materialized per chunk — activation memory
    scales with the microbatch, not the global batch)."""

    def step(params, opt_state, err_state, batch):
        mb = tcfg.microbatches

        def one(p, b):
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                p, b, cfg, remat=tcfg.remat)
            return l, g

        if mb == 1:
            loss, grads = one(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]),
                batch)

            def body(carry, b):
                loss_acc, gacc = carry
                l, g = one(params, b)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, gacc, g)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, gsum), _ = jax.lax.scan(body, (jnp.zeros(()), zero), split)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, gsum)

        if tcfg.grad_compression:
            grads, err_state = compress.apply(grads, err_state)
        params, opt_state, om = apply_updates(params, grads, opt_state,
                                              opt_cfg)
        return params, opt_state, err_state, {"loss": loss, **om}

    return step


def train(cfg: ModelConfig, tcfg: TrainConfig,
          data_cfg: DataConfig | None = None,
          opt_cfg: AdamWConfig | None = None,
          on_metrics: Callable | None = None) -> TrainResult:
    opt_cfg = opt_cfg or AdamWConfig(warmup_steps=5, total_steps=tcfg.steps)
    data_cfg = data_cfg or DataConfig(global_batch=4, seq_len=32)
    data = SyntheticLM(cfg, data_cfg)

    params = init_params(jax.random.PRNGKey(tcfg.seed), cfg)
    opt_state = init_state(params)
    err_state = compress.init_error(params) if tcfg.grad_compression else {}
    start = 0
    restored_from = None
    if tcfg.ckpt_dir and ckpt.latest_step(tcfg.ckpt_dir) is not None:
        (params, opt_state), start = ckpt.restore(
            tcfg.ckpt_dir, (params, opt_state))
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        restored_from = start

    step_fn = jax.jit(build_accum_step(cfg, opt_cfg, tcfg))
    losses = []
    stragglers = []
    ema = None
    pending = None
    for step in range(start, tcfg.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in
                 data.global_batch(step).items()}
        params, opt_state, err_state, metrics = step_fn(
            params, opt_state, err_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        # straggler watchdog (EMA of step time, ignoring the compile step)
        if step > start + 1:
            if ema is not None and dt > tcfg.straggler_factor * ema:
                stragglers.append((step, dt, ema))
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        if on_metrics:
            on_metrics(step, {**metrics, "step_time": dt})
        if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt.save(tcfg.ckpt_dir, step + 1,
                                (params, opt_state),
                                blocking=not tcfg.async_ckpt)
    if pending is not None:
        pending.join()
    return TrainResult(losses=losses, restored_from=restored_from,
                       stragglers=stragglers, steps_run=tcfg.steps - start)
