"""olmo-1b [dense] — 16L d=2048 16H (GQA kv=16) ff=8192 vocab=50304.
Non-parametric LayerNorm [arXiv:2402.00838]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50304,
    pattern=(("attn", "swiglu"),),
    norm="layernorm_np",
    rope_theta=10_000.0,
    dtype="bfloat16",
)
