"""recurrentgemma-2b [hybrid] — 26L d=2560 10H (MQA kv=1) ff=7680
vocab=256000; RG-LRU + local attention, pattern (rec, rec, local) 1:2
[arXiv:2402.19427]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000,
    pattern=(("rglru", "swiglu"), ("rglru", "swiglu"), ("local", "swiglu")),
    window=2048, d_rnn=2560, conv_width=4,
    tie_embeddings=True,
    head_dim=256,
    subquadratic=True,
    dtype="bfloat16",
)
