"""xlstm-1.3b [ssm] — 48L d=2048 4H, sLSTM + mLSTM blocks (7:1), d_ff=0
(cells carry their own projections) [arXiv:2405.04517]."""
from repro.models.config import ModelConfig

_PATTERN = tuple([("mlstm", "none")] * 7 + [("slstm", "none")])

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    pattern=_PATTERN,
    subquadratic=True,
    dtype="bfloat16",
)
