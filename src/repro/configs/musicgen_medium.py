"""musicgen-medium [audio] — 48L d=1536 24H ff=6144 vocab=2048; decoder
over EnCodec tokens (4 codebooks, delay-pattern stub) [arXiv:2306.05284]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    pattern=(("attn", "gelu"),),
    n_codebooks=4,
    dtype="bfloat16",
)
