"""Architecture registry + the assigned input-shape sets.

Every (arch × shape) cell is defined here; ``applicable()`` encodes the
task-spec skips (long_500k requires sub-quadratic attention; all archs
here are decoders so decode shapes always apply).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "olmo_1b", "minicpm3_4b", "stablelm_12b", "qwen2_0_5b", "internvl2_2b",
    "recurrentgemma_2b", "xlstm_1_3b", "musicgen_medium", "arctic_480b",
    "mixtral_8x22b",
]

# canonical external names (task spec) -> module ids
ALIASES = {
    "olmo-1b": "olmo_1b", "minicpm3-4b": "minicpm3_4b",
    "stablelm-12b": "stablelm_12b", "qwen2-0.5b": "qwen2_0_5b",
    "internvl2-2b": "internvl2_2b", "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-1.3b": "xlstm_1_3b", "musicgen-medium": "musicgen_medium",
    "arctic-480b": "arctic_480b", "mixtral-8x22b": "mixtral_8x22b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str):
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason).  long_500k only for sub-quadratic archs."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention architecture: 524288-token "
                       "decode is quadratic-cost; skipped per task spec "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""


def all_cells():
    for a in ARCH_IDS:
        for s in SHAPES:
            yield a, s
