"""internvl2-2b [vlm] — InternLM2 backbone: 24L d=2048 16H (GQA kv=8)
ff=8192 vocab=92553; InternViT frontend is a STUB (precomputed patch
embeddings via input_specs) [arXiv:2404.16821]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553,
    pattern=(("attn", "swiglu"),),
    frontend="vision", frontend_len=256,   # 256 patch-embedding positions
    rope_theta=1_000_000.0,
    dtype="bfloat16",
)
