from .registry import (ALIASES, ARCH_IDS, SHAPES, ShapeSpec, all_cells,
                       applicable, get_config)
