from .config import MLAConfig, ModelConfig, MoEConfig
from .lm import (decode_step, forward, init_cache, init_params, loss_fn)

__all__ = ["MLAConfig", "ModelConfig", "MoEConfig", "decode_step", "forward",
           "init_cache", "init_params", "loss_fn"]
