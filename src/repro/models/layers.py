"""Common layers: norms, rotary embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"]).astype(dt)


def layernorm_np(x, eps=1e-5):
    """Non-parametric LayerNorm (OLMo): no scale, no bias."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm_init(kind: str, d: int):
    return rmsnorm_init(d) if kind == "rmsnorm" else {}


def norm_apply(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm_np(x)


def dense_init(key, d_in, d_out, bias=False, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def causal_mask(s_q: int, s_k: int, q_offset=0, window: int = 0):
    """(s_q, s_k) boolean mask; True = attend.  window>0 = sliding window."""
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_k)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m
