"""Channel mixers: SwiGLU / GeLU MLPs and GShard-style top-k MoE.

The MoE uses grouped, capacity-bounded one-hot dispatch (GShard/GSPMD):
expert weights carry a leading expert dimension that sharding policies map
to the model axis (expert parallelism); the dispatch/combine einsums then
lower to the alltoall patterns whose cost model this paper formalizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense, dense_init

# Optional GSPMD constraints for the expert computation, set by the launch
# layer (see sharding.policies.set_moe_constraints): (token_axes, expert_ax).
_MOE_CONSTRAINTS: dict = {}


def set_moe_constraints(token_axes=None, expert_axis=None):
    _MOE_CONSTRAINTS.clear()
    if token_axes or expert_axis:
        _MOE_CONSTRAINTS.update(tokens=token_axes, experts=expert_axis)


def _constrain(x, spec_entries):
    if not _MOE_CONSTRAINTS:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec_entries))
    except Exception:
        return x


def swiglu_init(key, d, f, dtype):
    ks = jax.random.split(key, 3)
    return {"wi": dense_init(ks[0], d, f, dtype=dtype),
            "wg": dense_init(ks[1], d, f, dtype=dtype),
            "wo": dense_init(ks[2], f, d, dtype=dtype)}


def swiglu_apply(params, x):
    return dense(params["wo"],
                 jax.nn.silu(dense(params["wg"], x)) * dense(params["wi"], x))


def gelu_init(key, d, f, dtype):
    ks = jax.random.split(key, 2)
    return {"wi": dense_init(ks[0], d, f, dtype=dtype),
            "wo": dense_init(ks[1], f, d, dtype=dtype)}


def gelu_apply(params, x):
    return dense(params["wo"], jax.nn.gelu(dense(params["wi"], x)))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    D, F, E = cfg.d_model, cfg.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(D)
    return {
        "router": dense_init(ks[0], D, E, dtype=jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, D, F)) * scale).astype(dtype),
        "wg": (jax.random.normal(ks[2], (E, D, F)) * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, F, D)) * (1.0 / jnp.sqrt(F))
               ).astype(dtype),
    }


def moe_apply(params, x, cfg: ModelConfig, *, no_drop: bool = False):
    """x: (B, S, D) -> (B, S, D), plus router aux loss.

    Grouped dispatch: tokens are reshaped to (G, g) groups; each group
    dispatches to per-expert capacity buffers with one-hot matmuls.
    ``no_drop=True`` (decode) sizes capacity so no token is ever dropped.
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    tokens = x.reshape(-1, D)
    T = tokens.shape[0]
    g = min(m.group_size, T)
    G = T // g
    tokens = tokens[: G * g].reshape(G, g, D)

    logits = (tokens.astype(jnp.float32) @ params["router"]["w"])  # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                  # (G,g,K)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    C = g if no_drop else max(int(K * g * m.capacity_factor / E), 1)
    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)          # (G,g,K,E)
    flat = onehot.reshape(G, g * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - 1                   # (G,gK,E)
    pos = (pos_in_expert.reshape(G, g, K, E) * onehot).sum(-1)     # (G,g,K)
    keep = pos < C
    gate_vals = gate_vals * keep

    # dispatch/combine one-hot tensors (G, g, K, E, C)
    disp_k = (jax.nn.one_hot(gate_idx, E, dtype=tokens.dtype)[..., None]
              * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                               dtype=tokens.dtype)[..., None, :C])
    disp = disp_k.sum(2)                                           # (G,g,E,C)
    expert_in = jnp.einsum("gtec,gtd->gecd", disp, tokens)         # (G,E,C,D)
    tok_ax = _MOE_CONSTRAINTS.get("tokens")
    exp_ax = _MOE_CONSTRAINTS.get("experts")
    # Pin the expert buffers to (tokens over data, experts over model):
    # every device computes its expert shard for its token shard with NO
    # weight gathering and NO buffer gathering (EP done right).
    expert_in = _constrain(expert_in, (tok_ax, exp_ax, None, None))

    h = jnp.einsum("gecd,edf->gecf", expert_in, params["wg"])
    hi = jnp.einsum("gecd,edf->gecf", expert_in, params["wi"])
    h = _constrain(jax.nn.silu(h) * hi, (tok_ax, exp_ax, None, None))
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wo"])     # (G,E,C,D)
    expert_out = _constrain(expert_out, (tok_ax, exp_ax, None, None))

    comb = (disp_k * gate_vals.astype(tokens.dtype)[..., None, None]).sum(2)
    out = jnp.einsum("gtec,gecd->gtd", comb, expert_out)
    out = out.reshape(G * g, D)
    if G * g < T:
        out = jnp.concatenate(
            [out, jnp.zeros((T - G * g, D), out.dtype)], axis=0)
    out = out.reshape(B, S, D)

    # load-balancing auxiliary loss (Switch/GShard style)
    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(gate_idx[..., 0], E).mean(axis=(0, 1))
    aux = m.router_aux_weight * E * jnp.sum(me * ce)
    return out, aux
