"""Decoder LM assembly: heterogeneous block stacks, scanned layers,
training forward/loss and cached decode.

Layer stacking: the config's block *pattern* repeats ``pattern_repeats``
times; the repeated params are stacked with a leading repeat dimension and
consumed by ``lax.scan`` (compile-once-per-pattern — essential for the
62-layer dry-runs), with any remainder layers unrolled.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention, mlp, recurrent
from .config import ModelConfig
from .layers import dense, dense_init, norm_apply, norm_init

SEQ_INIT = {"attn": attention.gqa_init, "swa": attention.gqa_init,
            "local": attention.gqa_init, "mla": attention.mla_init,
            "rglru": recurrent.rglru_init, "mlstm": recurrent.mlstm_init,
            "slstm": recurrent.slstm_init}
SEQ_APPLY = {"attn": attention.gqa_apply, "swa": attention.gqa_apply,
             "local": attention.gqa_apply, "mla": attention.mla_apply,
             "rglru": recurrent.rglru_apply, "mlstm": recurrent.mlstm_apply,
             "slstm": recurrent.slstm_apply}
SEQ_CACHE = {"attn": attention.gqa_cache_init, "swa": attention.gqa_cache_init,
             "local": attention.gqa_cache_init,
             "mla": attention.mla_cache_init,
             "rglru": recurrent.rglru_cache_init,
             "mlstm": recurrent.mlstm_cache_init,
             "slstm": recurrent.slstm_cache_init}
SEQ_DECODE = {"attn": attention.gqa_decode, "swa": attention.gqa_decode,
              "local": attention.gqa_decode, "mla": attention.mla_decode,
              "rglru": recurrent.rglru_decode,
              "mlstm": recurrent.mlstm_decode,
              "slstm": recurrent.slstm_decode}


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _block_init(key, seq_kind: str, chan_kind: str, cfg: ModelConfig, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {
        "norm1": norm_init(cfg.norm, cfg.d_model),
        "seq": SEQ_INIT[seq_kind](k1, cfg, dt),
    }
    if chan_kind != "none":
        p["norm2"] = norm_init(cfg.norm, cfg.d_model)
    if chan_kind == "swiglu":
        p["chan"] = mlp.swiglu_init(k2, cfg.d_model, cfg.d_ff, dt)
    elif chan_kind == "gelu":
        p["chan"] = mlp.gelu_init(k2, cfg.d_model, cfg.d_ff, dt)
    elif chan_kind == "moe":
        p["chan"] = mlp.moe_init(k2, cfg, dt)
    elif chan_kind == "moe+dense":
        p["chan"] = mlp.moe_init(k2, cfg, dt)
        p["chan_dense"] = mlp.swiglu_init(k3, cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    n_pat = len(cfg.pattern)
    reps = cfg.pattern_repeats
    keys = jax.random.split(key, reps * n_pat + len(cfg.remainder) + 3)
    ki = iter(range(len(keys)))

    stacked = []
    for (seq, chan) in cfg.pattern:
        per_rep = [_block_init(keys[next(ki)], seq, chan, cfg, dt)
                   for _ in range(reps)]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)
                       if reps > 1 else
                       jax.tree.map(lambda x: x[None], per_rep[0]))
    remainder = [_block_init(keys[next(ki)], seq, chan, cfg, dt)
                 for (seq, chan) in cfg.remainder]

    params = {
        "embed": dense_init(keys[next(ki)], cfg.vocab, cfg.d_model,
                            scale=0.02, dtype=dt),
        "blocks": tuple(stacked),
        "remainder": remainder,
        "final_norm": norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[next(ki)], cfg.d_model,
                                       cfg.vocab, scale=0.02, dtype=dt)
    if cfg.frontend:
        params["frontend_proj"] = dense_init(keys[next(ki)], cfg.d_model,
                                             cfg.d_model, dtype=dt)
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _block_apply(p, x, seq_kind, chan_kind, cfg: ModelConfig):
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(cfg.norm, p.get("norm1"), x)
    window = cfg.window if seq_kind in ("swa", "local") else 0
    x = x + SEQ_APPLY[seq_kind](p["seq"], h, cfg, window=window)
    if chan_kind == "none":
        return x, aux
    h = norm_apply(cfg.norm, p.get("norm2"), x)
    if chan_kind in ("moe", "moe+dense"):
        y, aux = mlp.moe_apply(p["chan"], h, cfg)
        if chan_kind == "moe+dense":
            y = y + mlp.swiglu_apply(p["chan_dense"], h)
    elif chan_kind == "swiglu":
        y = mlp.swiglu_apply(p["chan"], h)
    else:
        y = mlp.gelu_apply(p["chan"], h)
    return x + y, aux


def embed_inputs(params, batch, cfg: ModelConfig):
    """tokens (B,S) [+ frontend embeds (B,F,D)] -> (B,S,D)."""
    tokens = batch["tokens"]
    if cfg.n_codebooks > 1:
        x = params["embed"]["w"][tokens].sum(axis=2)   # (B,S,cb) EnCodec stub
    else:
        x = params["embed"]["w"][tokens]
    if cfg.frontend and "frontend_embeds" in batch:
        fe = dense(params["frontend_proj"],
                   batch["frontend_embeds"].astype(x.dtype))
        x = jnp.concatenate([fe, x[:, cfg.frontend_len:]], axis=1)
    return x


def forward(params, batch, cfg: ModelConfig, *, remat: bool = True,
            unroll: bool = False):
    """Full forward -> (logits, aux_loss).  ``unroll=True`` replaces the
    layer scan with a Python loop (roofline probes: XLA cost analysis
    counts while-loop bodies once, so loop-free modules give true totals).
    """
    x = embed_inputs(params, batch, cfg)
    aux0 = jnp.zeros((), jnp.float32)

    def super_step(carry, layer_ps):
        x, aux = carry
        for pos, (seq, chan) in enumerate(cfg.pattern):
            x, a = _block_apply(layer_ps[pos], x, seq, chan, cfg)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(super_step) if remat else super_step
    if unroll:
        carry = (x, aux0)
        for r in range(cfg.pattern_repeats):
            layer_ps = jax.tree.map(lambda v: v[r], params["blocks"])
            carry, _ = body(carry, layer_ps)
        x, aux_total = carry
    else:
        (x, aux_total), _ = jax.lax.scan(body, (x, aux0), params["blocks"])

    for p, (seq, chan) in zip(params["remainder"], cfg.remainder):
        p = jax.tree.map(lambda v: v, p)
        x, a = _block_apply(p, x, seq, chan, cfg)
        aux_total = aux_total + a

    x = norm_apply(cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["w"].T
    else:
        logits = dense(params["lm_head"], x)
    return logits, aux_total


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True,
            unroll: bool = False):
    logits, aux = forward(params, batch, cfg, remat=remat, unroll=unroll)
    labels = batch["labels"]
    if cfg.n_codebooks > 1:
        labels = labels[..., 0]      # audio stub: predict first codebook
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (one token with caches)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = _dtype(cfg)
    caches = []
    for (seq, chan) in cfg.pattern:
        per_rep = [SEQ_CACHE[seq](cfg, batch, max_len, dt)
                   for _ in range(cfg.pattern_repeats)]
        caches.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)
                      if cfg.pattern_repeats > 1
                      else jax.tree.map(lambda x: x[None], per_rep[0]))
    rem = [SEQ_CACHE[seq](cfg, batch, max_len, dt)
           for (seq, chan) in cfg.remainder]
    return {"blocks": tuple(caches), "remainder": rem}


def _decode_block(p, c, x, pos, seq, chan, cfg, active=None):
    h = norm_apply(cfg.norm, p.get("norm1"), x)
    c2, y = SEQ_DECODE[seq](p["seq"], c, h, pos, cfg, active=active)
    x = x + y
    if chan != "none":
        h = norm_apply(cfg.norm, p.get("norm2"), x)
        if chan in ("moe", "moe+dense"):
            y, _ = mlp.moe_apply(p["chan"], h, cfg, no_drop=True)
            if chan == "moe+dense":
                y = y + mlp.swiglu_apply(p["chan_dense"], h)
        elif chan == "swiglu":
            y = mlp.swiglu_apply(p["chan"], h)
        else:
            y = mlp.gelu_apply(p["chan"], h)
        x = x + y
    return c2, x


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, active=None,
                unroll: bool = False):
    """tokens: (B, 1) (or (B,1,n_codebooks)); pos: scalar or (B,) int32
    positions; active: optional (B,) bool row mask (continuous batching —
    inactive rows' recurrent states are frozen).  Returns (logits, cache)."""
    x = embed_inputs(params, {"tokens": tokens}, cfg)

    def super_step(x, pcs):
        ps, cs = pcs
        ncs = []
        for posi, (seq, chan) in enumerate(cfg.pattern):
            c2, x = _decode_block(ps[posi], cs[posi], x, pos, seq, chan, cfg,
                                  active=active)
            ncs.append(c2)
        return x, tuple(ncs)

    if unroll:
        ncs_all = []
        for r in range(cfg.pattern_repeats):
            pcs = jax.tree.map(lambda v: v[r],
                               (params["blocks"], cache["blocks"]))
            x, ncs = super_step(x, pcs)
            ncs_all.append(ncs)
        new_caches = jax.tree.map(lambda *vs: jnp.stack(vs), *ncs_all)
    else:
        x, new_caches = jax.lax.scan(super_step, x,
                                     (params["blocks"], cache["blocks"]))

    new_rem = []
    for p, c, (seq, chan) in zip(params["remainder"], cache["remainder"],
                                 cfg.remainder):
        c2, x = _decode_block(p, c, x, pos, seq, chan, cfg, active=active)
        new_rem.append(c2)

    x = norm_apply(cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["w"].T
    else:
        logits = dense(params["lm_head"], x)
    return logits, {"blocks": new_caches, "remainder": new_rem}
