"""Recurrent sequence mixers: RG-LRU (RecurrentGemma/Griffin) and xLSTM.

RG-LRU:  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
  with a_t = exp(-c · softplus(Λ) · r_t) — a *linear* recurrence in h, so
  training uses jax.lax.associative_scan (log-time); decode is O(1)/token,
  which is what makes the long_500k shape feasible.

mLSTM: matrix-memory LSTM (xLSTM).  Training uses the parallel (quadratic)
  form with log-domain stabilization; decode updates (C, n, m) per token.
sLSTM: scalar-memory LSTM with recurrent gate connections — inherently
  sequential (lax.scan), as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import causal_mask, dense, dense_init

# ---------------------------------------------------------------------------
# RG-LRU + temporal conv (Griffin recurrent block)
# ---------------------------------------------------------------------------

_C_RGLRU = 8.0


def rglru_init(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    R = cfg.d_rnn or D
    ks = jax.random.split(key, 6)
    return {
        "wx": dense_init(ks[0], D, R, dtype=dtype),
        "wy": dense_init(ks[1], D, R, dtype=dtype),      # output gate branch
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, R)) * 0.1
                 ).astype(dtype),
        "w_input_gate": dense_init(ks[3], R, R, scale=0.01, dtype=dtype),
        "w_rec_gate": dense_init(ks[4], R, R, scale=0.01, dtype=dtype),
        # Λ init so that a^c spans ~(0.9, 0.999)
        "lam": jnp.asarray(
            np.log(np.expm1(-np.log(np.random.RandomState(0)
                                    .uniform(0.9, 0.999, R) ** (1 / _C_RGLRU)))),
            dtype=jnp.float32),
        "wo": dense_init(ks[5], R, D, dtype=dtype),
    }


def _conv1d(x, w):
    """Causal depthwise temporal conv; x: (B,S,R), w: (W,R)."""
    W = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pads[:, i: i + x.shape[1]] * w[i]
    return out


def _rglru_coeffs(params, xr):
    r = jax.nn.sigmoid(dense(params["w_rec_gate"], xr).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["w_input_gate"], xr).astype(jnp.float32))
    log_a = -_C_RGLRU * r * jax.nn.softplus(params["lam"])  # (B,S,R) fp32
    a = jnp.exp(log_a)
    gated = (xr.astype(jnp.float32) * i) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, gated


def rglru_apply(params, x, cfg: ModelConfig, **_):
    """Training path: associative scan over the sequence."""
    B, S, D = x.shape
    xr = dense(params["wx"], x)
    xr = _conv1d(xr, params["conv"])
    a, gated = _rglru_coeffs(params, xr)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = h.astype(x.dtype)
    y = h * jax.nn.gelu(dense(params["wy"], x))
    return dense(params["wo"], y)


def rglru_cache_init(cfg: ModelConfig, batch, max_len, dtype):
    R = cfg.d_rnn or cfg.d_model
    return {
        "h": jnp.zeros((batch, R), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, R), dtype),
    }


def _sel(active, new, old):
    if active is None:
        return new
    import jax.numpy as _jnp
    m = active.reshape((-1,) + (1,) * (new.ndim - 1))
    return _jnp.where(m, new, old)


def rglru_decode(params, cache, x, pos, cfg: ModelConfig, active=None):
    B, _, D = x.shape
    xr = dense(params["wx"], x)                       # (B,1,R)
    hist = jnp.concatenate([cache["conv"], xr], axis=1)
    xr_c = _conv1d(hist, params["conv"])[:, -1:, :]
    a, gated = _rglru_coeffs(params, xr_c)
    h = _sel(active, a[:, 0] * cache["h"] + gated[:, 0], cache["h"])
    conv = _sel(active, hist[:, 1:], cache["conv"])
    y = h[:, None].astype(x.dtype) * jax.nn.gelu(dense(params["wy"], x))
    out = dense(params["wo"], y)
    return {"h": h, "conv": conv}, out


# ---------------------------------------------------------------------------
# mLSTM (parallel training form, recurrent decode form)
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig, dtype):
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], D, D, dtype=dtype),
        "wk": dense_init(ks[1], D, D, dtype=dtype),
        "wv": dense_init(ks[2], D, D, dtype=dtype),
        "wi": dense_init(ks[3], D, H, scale=0.01, dtype=dtype),   # input gate
        "wf": dense_init(ks[4], D, H, scale=0.01, dtype=dtype),   # forget gate
        "wg": dense_init(ks[5], D, D, dtype=dtype),               # output gate
        "wo": dense_init(ks[6], D, D, dtype=dtype),
    }


def _mlstm_qkv(params, x, cfg):
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    q = dense(params["wq"], x).reshape(B, S, H, hd)
    k = dense(params["wk"], x).reshape(B, S, H, hd) / jnp.sqrt(hd)
    v = dense(params["wv"], x).reshape(B, S, H, hd)
    logi = dense(params["wi"], x).astype(jnp.float32)             # (B,S,H)
    logf = jax.nn.log_sigmoid(
        dense(params["wf"], x).astype(jnp.float32))               # (B,S,H)
    return q, k, v, logi, logf


def mlstm_apply(params, x, cfg: ModelConfig, **_):
    """Parallel form with log-domain stabilization (xLSTM eq. 19-27)."""
    B, S, D = x.shape
    H = cfg.n_heads
    q, k, v, logi, logf = _mlstm_qkv(params, x, cfg)
    F = jnp.cumsum(logf, axis=1)                                  # (B,S,H)
    # log decay matrix: D[s,t] = F_s - F_t + i_t  (t <= s)
    logD = (F[:, :, None] - F[:, None, :] + logi[:, None, :, :])
    mask = causal_mask(S, S)
    logD = jnp.where(mask[None, :, :, None], logD, -jnp.inf)
    m = jnp.max(logD, axis=2, keepdims=True)                      # stabilizer
    m = jnp.maximum(m, -1e30)
    Dmat = jnp.exp(logD - m)                                      # (B,S,S,H)
    scores = jnp.einsum("bshd,bthd->bsth", q, k).astype(jnp.float32) * Dmat
    norm = jnp.maximum(jnp.abs(scores.sum(axis=2)),
                       jnp.exp(-m[:, :, 0]))                      # (B,S,H)
    out = jnp.einsum("bsth,bthd->bshd", (scores / norm[:, :, None]
                                         ).astype(v.dtype), v)
    out = out.reshape(B, S, D)
    return dense(params["wo"], out * jax.nn.silu(dense(params["wg"], x)))


def mlstm_cache_init(cfg: ModelConfig, batch, max_len, dtype):
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(params, cache, x, pos, cfg: ModelConfig, active=None):
    B, _, D = x.shape
    H = cfg.n_heads
    hd = D // H
    q, k, v, logi, logf = _mlstm_qkv(params, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                           # (B,H,hd)
    logi, logf = logi[:, 0], logf[:, 0]                           # (B,H)
    m_new = jnp.maximum(logf + cache["m"], logi)
    f = jnp.exp(logf + cache["m"] - m_new)[..., None]
    i = jnp.exp(logi - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = f[..., None] * cache["C"] + i[..., None] * (
        vf[..., :, None] * kf[..., None, :])                      # (B,H,hd,hd)
    n = f * cache["n"] + i * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                      jnp.exp(-m_new))[..., None]
    out = (num / den).reshape(B, 1, D).astype(x.dtype)
    y = dense(params["wo"], out * jax.nn.silu(dense(params["wg"], x)))
    new = {"C": _sel(active, C, cache["C"]), "n": _sel(active, n, cache["n"]),
           "m": _sel(active, m_new, cache["m"])}
    return new, y


# ---------------------------------------------------------------------------
# sLSTM (sequential scan; block-diagonal recurrent weights per head)
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig, dtype):
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    ks = jax.random.split(key, 3)
    wx = dense_init(ks[0], D, 4 * D, dtype=dtype)       # z,i,f,o pre-acts
    r = (jax.random.normal(ks[1], (4, H, hd, hd)) / np.sqrt(hd)).astype(dtype)
    return {"wx": wx, "r": r,
            "wo": dense_init(ks[2], D, D, dtype=dtype)}


def _slstm_scan(params, pre, h0, c0, n0, m0, cfg):
    """pre: (B,S,4,H,hd) pre-activations; returns h over time + final state."""
    r = params["r"].astype(jnp.float32)

    def step(carry, xt):
        h, c, n, m = carry                              # (B,H,hd) fp32
        rec = jnp.einsum("bhd,ghde->bghe", h, r)        # (B,4,H,hd)
        zt, it, ft, ot = [xt[:, g] + rec[:, g] for g in range(4)]
        m_new = jnp.maximum(ft + m, it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(ft + m - m_new)
        c_new = f * c + i * jnp.tanh(zt)
        n_new = f * n + i
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    carry, hs = jax.lax.scan(step, (h0, c0, n0, m0),
                             jnp.moveaxis(pre.astype(jnp.float32), 1, 0))
    return carry, jnp.moveaxis(hs, 0, 1)                # (B,S,H,hd)


def slstm_apply(params, x, cfg: ModelConfig, **_):
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    pre = dense(params["wx"], x).reshape(B, S, 4, H, hd)
    zero = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H, hd), -1e30, jnp.float32)
    _, hs = _slstm_scan(params, pre, zero, zero, zero, m0, cfg)
    return dense(params["wo"], hs.reshape(B, S, D).astype(x.dtype))


def slstm_cache_init(cfg: ModelConfig, batch, max_len, dtype):
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    zero = jnp.zeros((batch, H, hd), jnp.float32)
    return {"h": zero, "c": zero, "n": zero,
            "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}


def slstm_decode(params, cache, x, pos, cfg: ModelConfig, active=None):
    B, _, D = x.shape
    H = cfg.n_heads
    hd = D // H
    pre = dense(params["wx"], x).reshape(B, 1, 4, H, hd)
    carry, hs = _slstm_scan(params, pre, cache["h"], cache["c"],
                            cache["n"], cache["m"], cfg)
    h, c, n, m = carry
    y = dense(params["wo"], hs.reshape(B, 1, D).astype(x.dtype))
    new = {"h": _sel(active, h, cache["h"]), "c": _sel(active, c, cache["c"]),
           "n": _sel(active, n, cache["n"]), "m": _sel(active, m, cache["m"])}
    return new, y
