"""Attention sequence mixers: GQA (full / sliding-window / local) and MLA.

Training path is a dense causal attention (optionally windowed); decode
path consumes a KV cache.  MLA (MiniCPM3/DeepSeek-style) caches the
*compressed latent* — its whole point — so its decode cache is
(B, S, kv_lora_rank + qk_rope_head_dim) regardless of head count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, causal_mask, dense, dense_init


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], D, H * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], D, KV * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], D, KV * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], H * hd, D, dtype=dtype),
    }


def _sdpa(q, k, v, mask):
    """q: (B,S,H,hd) k/v: (B,T,KV,hd); GQA head repetition via reshape."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)


def gqa_apply(params, x, cfg: ModelConfig, *, window=None, positions=None):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = dense(params["wq"], x).reshape(B, S, H, hd)
    k = dense(params["wk"], x).reshape(B, S, KV, hd)
    v = dense(params["wv"], x).reshape(B, S, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    w = cfg.window if window is None else window
    if cfg.attn_chunk and S > cfg.attn_chunk and S % cfg.attn_chunk == 0:
        out = _sdpa_chunked(q, k, v, w, cfg.attn_chunk)
    else:
        mask = causal_mask(S, S, window=w)
        out = _sdpa(q, k, v, mask)
    return dense(params["wo"], out.reshape(B, S, H * hd))


def _sdpa_chunked(q, k, v, window: int, chunk: int):
    """Query-block-chunked attention: the (S, S) score tensor never
    materializes — peak temp is (chunk, S) per head group.  This is the
    HLO-level equivalent of the Pallas flash kernel (kernels/
    flash_attention.py), used where Pallas cannot lower (dry-run on CPU);
    on TPU the kernel replaces it 1:1."""
    B, S, H, hd = q.shape
    n_chunks = S // chunk
    qc = q.reshape(B, n_chunks, chunk, H, hd)

    def body(_, args):
        qi, i = args
        q_off = i * chunk
        mask = causal_mask(chunk, S, q_offset=q_off, window=window)
        o = _sdpa(qi, k, v, mask)
        return None, o

    _, out = jax.lax.scan(
        body, None, (jnp.moveaxis(qc, 1, 0), jnp.arange(n_chunks)))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def gqa_cache_init(cfg: ModelConfig, batch, max_len, dtype):
    KV, hd = cfg.n_kv_heads, cfg.hd
    L = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, L, KV, hd), dtype),
        "v": jnp.zeros((batch, L, KV, hd), dtype),
    }


def _row_update(cache, new, slots):
    """Per-row cache write: cache (B,L,...), new (B,1,...), slots (B,)."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice(
            c, n, (s,) + (0,) * (c.ndim - 1)))(cache, new, slots)


def _as_vec(pos, B):
    pos = jnp.asarray(pos)
    return jnp.broadcast_to(pos, (B,)).astype(jnp.int32)


def gqa_decode(params, cache, x, pos, cfg: ModelConfig, active=None):
    """One-token decode.  x: (B, 1, D); pos: scalar or (B,) per-slot
    positions (continuous batching).

    With a sliding window the cache is a ring buffer of size ``window``
    (this is what makes `long_500k` feasible for SWA archs)."""
    B, _, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    L = cache["k"].shape[1]
    posv = _as_vec(pos, B)                               # (B,)
    positions = posv[:, None]
    q = dense(params["wq"], x).reshape(B, 1, H, hd)
    k = dense(params["wk"], x).reshape(B, 1, KV, hd)
    v = dense(params["wv"], x).reshape(B, 1, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    slot = posv % L if cfg.window else posv
    ck = _row_update(cache["k"], k, slot)
    cv = _row_update(cache["v"], v, slot)
    # valid = slots holding positions in (pos-L, pos], per row
    idx = jnp.arange(L)[None, :]
    if cfg.window:
        age = (slot[:, None] - idx) % L
        valid = age < jnp.minimum(posv[:, None] + 1, L)
    else:
        valid = idx <= posv[:, None]
    out = _sdpa_rowmask(q, ck, cv, valid)
    y = dense(params["wo"], out.reshape(B, 1, H * hd))
    return {"k": ck, "v": cv}, y


def _sdpa_rowmask(q, k, v, valid):
    """_sdpa with a per-row (B, T) key-validity mask."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qq = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qq, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], D, m.q_lora_rank, dtype=dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank, H * qk, dtype=dtype),
        "wkv_a": dense_init(ks[2], D, m.kv_lora_rank + m.qk_rope_head_dim,
                            dtype=dtype),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            H * (m.qk_nope_head_dim + m.v_head_dim),
                            dtype=dtype),
        "wo": dense_init(ks[4], H * m.v_head_dim, D, dtype=dtype),
    }


def _mla_qkv(params, x, positions, cfg: ModelConfig):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = dense(params["wq_b"], dense(params["wq_a"], x))
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = dense(params["wkv_a"], x)                       # latent + k_rope
    latent, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, latent, k_rope


def _mla_attend(params, q_nope, q_rope, latent, k_rope, mask, cfg):
    m = cfg.mla
    B, S = q_nope.shape[:2]
    T = latent.shape[1]
    H = cfg.n_heads
    kvb = dense(params["wkv_b"], latent).reshape(
        B, T, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
              + jnp.einsum("bshd,btxd->bhst", q_rope,
                           k_rope)).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)   # mask broadcastable (B,H,S,T)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", p, v)
    return dense(params["wo"], out.reshape(B, S, H * m.v_head_dim))


def mla_apply(params, x, cfg: ModelConfig, *, positions=None, **_):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope, latent, k_rope = _mla_qkv(params, x, positions, cfg)
    mask = causal_mask(S, S)[None, None]
    return _mla_attend(params, q_nope, q_rope, latent, k_rope, mask, cfg)


def mla_cache_init(cfg: ModelConfig, batch, max_len, dtype):
    m = cfg.mla
    return {
        "latent": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, 1, m.qk_rope_head_dim), dtype),
    }


def mla_decode(params, cache, x, pos, cfg: ModelConfig, active=None):
    B = x.shape[0]
    posv = _as_vec(pos, B)
    positions = posv[:, None]
    q_nope, q_rope, latent, k_rope = _mla_qkv(params, x, positions, cfg)
    cl = _row_update(cache["latent"], latent, posv)
    cr = _row_update(cache["k_rope"], k_rope, posv)
    T = cl.shape[1]
    valid = jnp.arange(T)[None, :] <= posv[:, None]      # (B, T)
    y = _mla_attend(params, q_nope, q_rope, cl, cr,
                    valid[:, None, None, :], cfg)
    return {"latent": cl, "k_rope": cr}, y
