"""Model configuration for all assigned architectures.

A single declarative config drives block construction; heterogeneous
layer stacks (hybrid/ssm archs) are expressed as a repeating *pattern* of
(sequence-mixer, channel-mixer) block kinds plus an optional remainder.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

SeqMixer = Literal["attn", "swa", "mla", "local", "rglru", "mlstm", "slstm"]
ChanMixer = Literal["swiglu", "gelu", "moe", "moe+dense", "none"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    group_size: int = 1024        # GShard-style dispatch groups
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[tuple[str, str], ...] = (("attn", "swiglu"),)
    head_dim: int | None = None       # default d_model // n_heads
    window: int = 0                   # sliding/local attention window (0=full)
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"             # rmsnorm | layernorm_np (non-parametric)
    rope_theta: float = 10_000.0
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    d_rnn: int = 0                    # RG-LRU width
    conv_width: int = 4               # temporal conv for RG-LRU
    frontend: str | None = None       # "vision" | "audio" (stub embeddings)
    frontend_len: int = 0             # prefix positions fed by the frontend
    n_codebooks: int = 1              # audio: EnCodec codebooks
    dtype: str = "float32"
    # Sub-quadratic? (drives the long_500k skip decision)
    subquadratic: bool = False
    # Perf knobs (hillclimb; see EXPERIMENTS.md §Perf)
    attn_chunk: int = 0          # >0: scan attention over query blocks

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> tuple[tuple[str, str], ...]:
        rem = self.n_layers - self.pattern_repeats * len(self.pattern)
        return self.pattern[:rem]

    def reduced(self, n_layers: int | None = None) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        scale = max(self.d_model // 64, 1)
        small_heads = max(self.n_heads // max(self.n_heads // 2, 1), 2)
        kv = max(1, self.n_kv_heads * small_heads // self.n_heads)
        moe = None
        if self.moe:
            moe = dataclasses.replace(self.moe, n_experts=4, group_size=16,
                                       capacity_factor=4.0)
        mla = None
        if self.mla:
            mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                            qk_nope_head_dim=8, qk_rope_head_dim=8,
                            v_head_dim=8)
        return dataclasses.replace(
            self,
            n_layers=n_layers or max(2 * len(self.pattern), len(self.pattern)),
            d_model=self.d_model // scale,
            n_heads=small_heads,
            n_kv_heads=kv,
            head_dim=None,
            d_ff=max(self.d_ff // scale, 8) if self.d_ff else 0,
            vocab=256,
            window=min(self.window, 8) if self.window else 0,
            mla=mla, moe=moe,
            d_rnn=self.d_rnn // scale if self.d_rnn else 0,
            frontend_len=4 if self.frontend else 0,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        total = V * D * (1 if self.tie_embeddings else 2)
        for (seq, chan) in (self.pattern * self.pattern_repeats +
                            self.remainder):
            if seq in ("attn", "swa", "local"):
                kvh = 1 if seq == "local" and self.n_kv_heads == 1 else self.n_kv_heads
                total += D * hd * self.n_heads + 2 * D * hd * kvh \
                    + self.n_heads * hd * D
            elif seq == "mla":
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                total += (D * m.q_lora_rank
                          + m.q_lora_rank * self.n_heads * qk
                          + D * (m.kv_lora_rank + m.qk_rope_head_dim)
                          + m.kv_lora_rank * self.n_heads
                          * (m.qk_nope_head_dim + m.v_head_dim)
                          + self.n_heads * m.v_head_dim * D)
            elif seq == "rglru":
                R = self.d_rnn or D
                total += 2 * D * R + R * self.conv_width + 2 * R + R * D
            elif seq in ("mlstm", "slstm"):
                total += 2 * D * 2 * D + 4 * D * D // 4  # up/down + cell (approx)
            if chan == "swiglu":
                total += 3 * D * F
            elif chan == "gelu":
                total += 2 * D * F
            elif chan in ("moe", "moe+dense"):
                total += self.moe.n_experts * 3 * D * F + D * self.moe.n_experts
                if chan == "moe+dense":
                    total += 3 * D * F
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if not self.moe:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        dense = self.param_count()
        n_moe = sum(1 for (_, c) in (self.pattern * self.pattern_repeats
                                     + self.remainder)
                    if c in ("moe", "moe+dense"))
        inactive = n_moe * (self.moe.n_experts - self.moe.top_k) * 3 * D * F
        return dense - inactive
