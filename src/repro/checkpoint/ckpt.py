"""Sharded checkpointing with async save and elastic restore.

Layout: one msgpack-framed .npz-style file per save ("shard files" in a
real deployment would be per-host; here the single-process container
writes one), plus a JSON manifest carrying the step, the mesh the state
was saved under, and the distributed type of every leaf.

**Elastic restore** is where the paper's machinery becomes a production
feature: when the restore mesh differs from the save mesh, every leaf's
layout change is a *redistribution problem*; `elastic.reshard_plan`
synthesizes the memory-bounded collective program for it (instead of the
gather-everything-then-slice a naive restore would do).
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
    else:
        out[prefix] = tree
    return out


def save(path: str | Path, step: int, state, *, blocking: bool = True,
         mesh_shape=None):
    """Write state (a pytree of arrays) + manifest.  With blocking=False
    the device->host copy happens synchronously but file I/O runs on a
    background thread (async checkpointing)."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    host = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": int(step),
        "time": time.time(),
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in host.items()},
    }

    def _write():
        tmp = path / f"ckpt-{step}.npz.tmp"
        final = path / f"ckpt-{step}.npz"
        with open(tmp, "wb") as f:
            np.savez(f, **{k.replace("/", "|"): v for k, v in host.items()})
        tmp.rename(final)
        (path / f"ckpt-{step}.json").write_text(json.dumps(manifest))
        latest = path / "LATEST"
        latest.write_text(str(step))

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(path: str | Path) -> int | None:
    p = Path(path) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(path: str | Path, like, step: int | None = None):
    """Restore into the structure of ``like`` (a pytree template)."""
    path = Path(path)
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    data = np.load(path / f"ckpt-{step}.npz")
    flat_like = _flatten(like)
    restored = {}
    for k in flat_like:
        key = k.replace("/", "|")
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {k}")
        restored[k] = data[key]

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}") for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(rebuild(v, f"{prefix}/{i}")
                         for i, v in enumerate(tree))
        if isinstance(tree, list):
            return [rebuild(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
        return restored[prefix]

    return rebuild(like), step
