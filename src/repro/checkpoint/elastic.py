"""Elastic re-scaling: reshard checkpointed state onto a different mesh.

Every leaf whose sharding changes between the save mesh and the restore
mesh is a redistribution problem  τ_saved ⤳ τ_new.  We synthesize the
memory-bounded plan with the paper's search (repro.core) and report the
aggregate transfer/memory savings vs the XLA-style fallback — on a 1000+
node cluster this is the difference between "reshard in place" and
"OOM while resharding the optimizer state".
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import Mesh as CMesh
from repro.core.api import plan_redistribution, plan_xla_baseline
from repro.core.dist_types import DistDim, DistType
from jax.sharding import PartitionSpec as P


def dist_type_of(shape, spec: P, mesh: CMesh) -> DistType:
    """PartitionSpec + global shape -> distributed type (paper syntax).
    PartitionSpec lists axes major-to-minor; DistDim wants minor-to-major."""
    dims = []
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for size, ent in zip(shape, entries):
        if ent is None:
            dims.append(DistDim(size, (), size))
        else:
            axes = (ent,) if isinstance(ent, str) else tuple(ent)
            prod = math.prod(mesh.size(a) for a in axes)
            dims.append(DistDim(size // prod, tuple(reversed(axes)), size))
    return DistType(tuple(dims))


@dataclasses.dataclass
class ReshardReport:
    n_leaves: int
    n_replanned: int
    ours_cost_elems: int        # Fig. 11 cost summed over leaves
    xla_cost_elems: int
    ours_peak_elems: int        # max per-device elements during reshard
    xla_peak_elems: int


def reshard_plan(leaf_shapes: dict, old_specs: dict, new_specs: dict,
                 mesh: CMesh) -> tuple[dict, ReshardReport]:
    """Plan the redistribution of every leaf; returns per-leaf plans and a
    cost/memory report comparing against the XLA-style baseline."""
    plans = {}
    ours_cost = xla_cost = 0
    ours_peak = xla_peak = 0
    replanned = 0
    for name, shape in leaf_shapes.items():
        t1 = dist_type_of(shape, old_specs[name], mesh)
        t2 = dist_type_of(shape, new_specs[name], mesh)
        if t1 == t2:
            continue
        replanned += 1
        r = plan_redistribution(t1, t2, mesh)
        b = plan_xla_baseline(t1, t2, mesh)
        plans[name] = r.plan
        ours_cost += r.plan.cost()
        xla_cost += b.cost()
        ours_peak = max(ours_peak, r.plan.height())
        xla_peak = max(xla_peak, b.height())
    report = ReshardReport(
        n_leaves=len(leaf_shapes), n_replanned=replanned,
        ours_cost_elems=ours_cost, xla_cost_elems=xla_cost,
        ours_peak_elems=ours_peak, xla_peak_elems=xla_peak)
    return plans, report
