"""flash_attention — blockwise causal attention (Pallas TPU kernel).

The framework's dominant compute hot-spot: the dry-run shows full-
attention HLO materializing (B, H, S, S) fp32 score tensors (the 85 GB
temp blow-up on stablelm train_4k).  This kernel keeps the working set in
VMEM: grid (B*H, S/q_block), each program streams K/V in k_block chunks
with the online-softmax recurrence, so HBM traffic is O(S·d) per head and
the MXU sees (q_block × d) @ (d × k_block) matmuls with dims padded to
128-multiples.

GQA: q heads are grouped onto kv heads by index map (no materialized
head repetition).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, q_block, k_block, seq_len,
                  scale, causal):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # (q_block, d)
    d = q.shape[-1]

    m = jnp.full((q_block,), NEG_INF, jnp.float32)
    l = jnp.zeros((q_block,), jnp.float32)
    acc = jnp.zeros((q_block, d), jnp.float32)

    n_k = seq_len // k_block
    # causal: key block j only contributes while j*k_block <= max q pos
    hi = jax.lax.min(((qi + 1) * q_block + k_block - 1) // k_block,
                     n_k) if causal else n_k

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * k_block, k_block),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * k_block, k_block),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                      # (q_block, k_block)
        if causal:
            qpos = qi * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, k_block), 0)
            kpos = j * k_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, k_block), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, hi, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "k_block",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 128,
                    k_block: int = 128, interpret: bool = False):
    """q: (B, H, S, d); k/v: (B, KV, S, d) with H % KV == 0."""
    B, H, S, d = q.shape
    KV = k.shape[1]
    G = H // KV
    q_block = min(q_block, S)
    k_block = min(k_block, S)
    assert S % q_block == 0 and S % k_block == 0
    scale = 1.0 / (d ** 0.5)

    grid = (B * H, S // q_block)
    q_spec = pl.BlockSpec((1, 1, q_block, d),
                          lambda bh, qi: (bh // H, bh % H, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, S, d),
                           lambda bh, qi: (bh // H, (bh % H) // G, 0, 0))
    out_spec = pl.BlockSpec((1, 1, q_block, d),
                            lambda bh, qi: (bh // H, bh % H, qi, 0))

    kern = functools.partial(
        _flash_kernel, q_block=q_block, k_block=k_block, seq_len=S,
        scale=scale, causal=causal)

    def kern3(q_ref, k_ref, v_ref, o_ref):
        # squeeze the leading (1, 1) block dims
        kern(q_ref.at[0, 0], k_ref.at[0, 0], v_ref.at[0, 0], o_ref.at[0, 0])

    return pl.pallas_call(
        kern3, grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
