"""tile_relayout — fused local chunk permutation (Pallas TPU kernel).

The paper's §8 P4 analysis shows XLA exploiting *local* reshape/transposes
around collectives; our physical plans likewise produce buffers that are
concatenations of tiles whose final device-local order may differ from the
order a collective produced (group-order vs target-order).  XLA emits a
copy chain (transpose+reshape) for this; on TPU we fuse it into ONE pass
over VMEM blocks, with the chunk permutation delivered via *scalar
prefetch* (SMEM) so the BlockSpec index map can route each output block to
its source block — zero extra HBM round-trips, arbitrary permutations.

Layout contract: x has shape (C * a, b) = C chunks of (a, b) stacked on
dim 0; output chunk k = input chunk perm[k].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _relayout_kernel(perm_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("perm", "interpret", "block_b"))
def tile_relayout(x: jax.Array, perm: tuple[int, ...], *,
                  block_b: int = 512, interpret: bool = False) -> jax.Array:
    """Permute C equal chunks along dim 0 of a 2-D array.

    grid = (C, ceil(b / block_b)); each program copies one (a, block_b)
    VMEM tile from input chunk perm[i] to output chunk i.
    """
    C = len(perm)
    rows, b = x.shape
    assert rows % C == 0, (rows, C)
    a = rows // C
    bb = min(block_b, b)
    grid = (C, pl.cdiv(b, bb))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((a, bb), lambda i, j, perm_ref:
                               (perm_ref[i], j))],
        out_specs=pl.BlockSpec((a, bb), lambda i, j, perm_ref: (i, j)),
    )
    return pl.pallas_call(
        _relayout_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(jnp.asarray(perm, jnp.int32), x)
