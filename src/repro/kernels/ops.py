"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (validation) and False on TPU
(real kernel lowering) — the call sites never need to care.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .rglru_scan import rglru_scan as _rglru
from .tile_relayout import tile_relayout as _relayout


def _default_interpret() -> bool:
    return jax.default_backend() == "cpu"


def tile_relayout(x, perm, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _relayout(x, tuple(perm), **kw)


def flash_attention(q, k, v, *, causal=True, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _flash(q, k, v, causal=causal, **kw)


def rglru_scan(a, b, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _rglru(a, b, **kw)
