"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tile_relayout_ref(x: jax.Array, perm: tuple[int, ...]) -> jax.Array:
    C = len(perm)
    a = x.shape[0] // C
    chunks = x.reshape(C, a, *x.shape[1:])
    return chunks[jnp.asarray(perm)].reshape(x.shape)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,H,S,d); k/v: (B,KV,S,d)."""
    B, H, S, d = q.shape
    KV = k.shape[1]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t, h_{-1} = 0; shapes (B, S, R)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype)
