"""rglru_scan — chunked linear-recurrence scan (Pallas TPU kernel).

h_t = a_t * h_{t-1} + b_t over the sequence, per (batch, channel) lane —
the RG-LRU/Griffin recurrence.  XLA's associative_scan materializes
log2(S) full-length intermediates in HBM; this kernel runs the recurrence
sequentially over S *inside VMEM* per (batch, channel-block) tile: one HBM
read of (a, b), one HBM write of h.  The channel dimension is the minor
(lane) axis, 128-aligned for the VPU; sequence chunks bound VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(a_ref, b_ref, o_ref, *, seq_chunk, seq_len):
    """Refs are (1, S, r_block) VMEM blocks; the recurrence runs over S in
    seq_chunk pieces, each processed sequentially in registers."""
    R = a_ref.shape[-1]

    def chunk_body(c, carry):
        h0, out = carry
        lo = c * seq_chunk
        a = jax.lax.dynamic_slice_in_dim(
            a_ref[0], lo, seq_chunk, axis=0).astype(jnp.float32)
        b = jax.lax.dynamic_slice_in_dim(
            b_ref[0], lo, seq_chunk, axis=0).astype(jnp.float32)

        def step(t, carry2):
            h, buf = carry2
            h = a[t] * h + b[t]
            buf = jax.lax.dynamic_update_slice_in_dim(
                buf, h[None], t, axis=0)
            return h, buf

        h, buf = jax.lax.fori_loop(
            0, seq_chunk, step,
            (h0, jnp.zeros((seq_chunk, R), jnp.float32)))
        out = jax.lax.dynamic_update_slice_in_dim(out, buf, lo, axis=0)
        return h, out

    h0 = jnp.zeros((R,), jnp.float32)
    out0 = jnp.zeros((seq_len, R), jnp.float32)
    _, out = jax.lax.fori_loop(0, seq_len // seq_chunk, chunk_body,
                               (h0, out0))
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("r_block", "seq_chunk",
                                             "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, *, r_block: int = 128,
               seq_chunk: int = 256, interpret: bool = False) -> jax.Array:
    """a, b: (B, S, R) -> h: (B, S, R) with h_t = a_t*h_{t-1} + b_t."""
    B, S, R = a.shape
    r_block = min(r_block, R)
    seq_chunk = min(seq_chunk, S)
    assert R % r_block == 0 and S % seq_chunk == 0
    grid = (B, R // r_block)

    spec = pl.BlockSpec((1, S, r_block), lambda i, j: (i, 0, j))
    kern = functools.partial(_scan_kernel, seq_chunk=seq_chunk, seq_len=S)

    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)
