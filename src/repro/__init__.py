"""repro — memory-efficient array redistribution, as a JAX framework.

Public surface:
  repro.core    — the paper's contribution (types, search, lowering, exec)
  repro.models  — the 10 assigned architectures
  repro.train   — distributed trainer (DP/TP/FSDP/EP, ZeRO-1, fault tolerance)
  repro.serve   — batched prefill/decode serving
  repro.launch  — production mesh, dry-run, entry points
"""

from repro.core import (Mesh, parse_type, plan_redistribution,
                        plan_xla_baseline)

__version__ = "1.0.0"


def redistribute(x, t1, t2, mesh, **kw):
    """Redistribute a jax.Array from distributed type t1 to t2 (lazy import
    so that planning-only users never touch jax device state)."""
    from repro.core.jax_exec import redistribute_array
    from repro.core.dist_types import Mesh as CMesh
    if isinstance(mesh, dict):
        mesh = CMesh.make(mesh)
    if isinstance(t1, str):
        t1 = parse_type(t1)
    if isinstance(t2, str):
        t2 = parse_type(t2)
    return redistribute_array(x, t1, t2, mesh, **kw)
