"""Weak semantic types (paper §5) and weak collective operations.

A weak type ``E[[τ]]`` is the equivalence class of base offset maps up to a
device permutation (Def. 5.2).  With a fixed globaltype, a weak type is
fully identified by the *localtype* (§7.2), so weak nodes are plain tuples
of per-dimension tile sizes.  Weak ops never include allpermute (Def. 5.3).

Weak ops are *multi-axis merged* (§7.1): they move an arbitrary factor
``m > 1`` whose prime decomposition maps onto mesh sub-axes.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from collections import Counter
from typing import Iterable

from .dist_types import DistType, Mesh, TypingError, prime_factors


@dataclasses.dataclass(frozen=True)
class WeakOp:
    """kind in {dynslice, allgather, alltoall}; moves factor ``m``.

    dynslice(i, m):       c_i /= m  (uses free mesh primes)
    allgather(i, m):      c_i *= m  (releases primes partitioning dim i)
    alltoall(i, j, m):    c_i *= m ; c_j /= m
    """
    kind: str
    i: int
    m: int
    j: int | None = None

    def __str__(self):
        if self.kind == "alltoall":
            return f"alltoall({self.i}->{self.j}, m={self.m})"
        return f"{self.kind}({self.i}, m={self.m})"


@functools.lru_cache(maxsize=None)
def divisors(n: int) -> tuple[int, ...]:
    out = []
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.append(d)
            if d != n // d:
                out.append(n // d)
        d += 1
    return tuple(sorted(out))


def mesh_prime_pool(mesh: Mesh) -> Counter:
    pool: Counter = Counter()
    for _, k in mesh.axes:
        pool.update(prime_factors(k))
    return pool


def used_primes(localtype: tuple[int, ...], globaltype: tuple[int, ...]) -> Counter:
    used: Counter = Counter()
    for c, s in zip(localtype, globaltype):
        if s % c != 0:
            raise TypingError(f"localtype {localtype} does not divide {globaltype}")
        used.update(prime_factors(s // c))
    return used


def free_primes(localtype, globaltype, pool: Counter) -> Counter:
    used = used_primes(localtype, globaltype)
    free = pool - used
    if sum((used - pool).values()):
        raise TypingError(
            f"localtype {localtype} uses primes not in the mesh: {used - pool}")
    return free


def fits(m: int, pool: Counter) -> bool:
    return not (Counter(prime_factors(m)) - pool)


def weak_apply(op: WeakOp, c: tuple[int, ...], globaltype, pool: Counter
               ) -> tuple[int, ...]:
    """Apply a weak op to a localtype; checks preconditions."""
    c = list(c)
    if op.m <= 1:
        raise TypingError("weak ops must move a factor m > 1")
    if op.kind == "dynslice":
        if c[op.i] % op.m:
            raise TypingError(f"dynslice: {c[op.i]} % {op.m} != 0")
        if not fits(op.m, free_primes(tuple(c), globaltype, pool)):
            raise TypingError(f"dynslice: no free axes for factor {op.m}")
        c[op.i] //= op.m
    elif op.kind == "allgather":
        q = globaltype[op.i] // c[op.i]
        if q % op.m:
            raise TypingError(f"allgather: dim {op.i} partition {q} % {op.m} != 0")
        c[op.i] *= op.m
    elif op.kind == "alltoall":
        if op.j is None or op.j == op.i:
            raise TypingError("alltoall needs distinct dims")
        q = globaltype[op.i] // c[op.i]
        if q % op.m:
            raise TypingError(f"alltoall: dim {op.i} partition {q} % {op.m} != 0")
        if c[op.j] % op.m:
            raise TypingError(f"alltoall: {c[op.j]} % {op.m} != 0")
        c[op.i] *= op.m
        c[op.j] //= op.m
    else:
        raise TypingError(f"unknown weak op {op.kind!r}")
    return tuple(c)


def weak_apply_seq(ops: Iterable[WeakOp], c: tuple[int, ...], globaltype,
                   pool: Counter) -> list[tuple[int, ...]]:
    out = [tuple(c)]
    for op in ops:
        out.append(weak_apply(op, out[-1], globaltype, pool))
    return out


def plan_height(ops, c0, globaltype, pool) -> int:
    """Def. 4.4 — max localsize along the sequence."""
    return max(math.prod(c) for c in weak_apply_seq(ops, c0, globaltype, pool))


def plan_cost(ops, c0, globaltype, pool) -> int:
    """Fig. 11 cost of a weak plan."""
    from .costmodel import step_cost
    types = weak_apply_seq(ops, c0, globaltype, pool)
    total = 0
    for op, cin, cout in zip(ops, types[:-1], types[1:]):
        total += step_cost(op.kind, math.prod(cin), math.prod(cout))
    return total


def weak_of(t: DistType) -> tuple[int, ...]:
    return t.localtype()
