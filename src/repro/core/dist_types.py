"""Distributed array types (paper Fig. 6).

A distributed dimension ``c{x1,...,xn}s`` describes a global dimension of
size ``s`` partitioned over mesh axes ``x1..xn`` (listed minor-to-major,
i.e. the *first* axis has the smallest stride) leaving a per-device tile of
size ``c``.  A distributed type is a list of distributed dimensions.

Well-formedness (Fig. 7b):
  * ``c * prod(size(xi)) == s`` for every dimension,
  * every mesh axis appears at most once in the whole type.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
import re
from typing import Iterable, Mapping, Sequence


class TypingError(Exception):
    """Raised when a distributed type or collective is ill-formed."""


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Mesh:
    """A logical device mesh: ordered named axes with sizes.

    The device order is the row-major ravel of the axes in declaration
    order (first axis outermost), matching ``jax.sharding.Mesh``.
    """

    axes: tuple[tuple[str, int], ...]

    def __post_init__(self):
        names = [a for a, _ in self.axes]
        if len(set(names)) != len(names):
            raise TypingError(f"duplicate mesh axis names: {names}")
        for a, k in self.axes:
            if k < 1:
                raise TypingError(f"mesh axis {a} has non-positive size {k}")

    @staticmethod
    def make(spec: Mapping[str, int] | Iterable[tuple[str, int]]) -> "Mesh":
        if isinstance(spec, Mapping):
            return Mesh(tuple(spec.items()))
        return Mesh(tuple(spec))

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(a for a, _ in self.axes)

    def size(self, name: str) -> int:
        for a, k in self.axes:
            if a == name:
                return k
        raise TypingError(f"unknown mesh axis {name!r}")

    def __contains__(self, name: str) -> bool:
        return any(a == name for a, _ in self.axes)

    @property
    def nelems(self) -> int:
        return math.prod(k for _, k in self.axes)

    def coords(self) -> Iterable[tuple[int, ...]]:
        """All device coordinates in device-id (row-major) order."""
        return itertools.product(*(range(k) for _, k in self.axes))

    def coord_of(self, device_id: int) -> tuple[int, ...]:
        out = []
        for _, k in reversed(self.axes):
            out.append(device_id % k)
            device_id //= k
        return tuple(reversed(out))

    def id_of(self, coord: Sequence[int]) -> int:
        dev = 0
        for (_, k), c in zip(self.axes, coord):
            dev = dev * k + c
        return dev

    def decompose_primes(self) -> tuple["Mesh", dict[str, tuple[str, ...]]]:
        """Principle 1: factor every axis into prime-size sub-axes.

        Returns the decomposed mesh (same device order: sub-axes of an axis
        are laid out contiguously, minor sub-axis fastest) and a map from
        original axis name to its sub-axis names (minor-to-major).

        An axis ``x: 12`` becomes sub-axes ``x@0:2, x@1:2, x@2:3`` where the
        *last listed* sub-axis in the mesh ordering is the fastest-varying.
        We name sub-axes so that ``x@0`` is the *minor-most* (stride-1 within
        x's coordinate).
        """
        new_axes: list[tuple[str, int]] = []
        submap: dict[str, tuple[str, ...]] = {}
        for name, k in self.axes:
            fs = prime_factors(k)
            if len(fs) <= 1:
                new_axes.append((name, k))
                submap[name] = (name,)
            else:
                subs = tuple(f"{name}@{i}" for i in range(len(fs)))
                # Device order: original axis coordinate c maps to sub-coords
                # with x@0 minor (fastest).  Row-major ravel lists the last
                # axis fastest, so append major-to-minor: x@last .. x@0.
                for i in reversed(range(len(fs))):
                    new_axes.append((subs[i], fs[i]))
                submap[name] = subs
        # ``new_axes`` currently groups each original axis contiguously with
        # the major sub-axis first, preserving the global device order.
        return Mesh(tuple(new_axes)), submap


@functools.lru_cache(maxsize=None)
def prime_factors(n: int) -> tuple[int, ...]:
    """Prime factorization, ascending, with multiplicity."""
    if n < 1:
        raise ValueError(f"cannot factor {n}")
    out: list[int] = []
    d = 2
    while d * d <= n:
        while n % d == 0:
            out.append(d)
            n //= d
        d += 1
    if n > 1:
        out.append(n)
    return tuple(out)


# ---------------------------------------------------------------------------
# Distributed dimensions and types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DistDim:
    """A distributed dimension ``tile{axes}global``; axes minor-to-major."""

    tile: int
    axes: tuple[str, ...]
    global_: int

    def __str__(self) -> str:
        if not self.axes:
            return f"{self.global_}" if self.tile == self.global_ else (
                f"{self.tile}{{}}{self.global_}")
        return f"{self.tile}{{{','.join(self.axes)}}}{self.global_}"


@dataclasses.dataclass(frozen=True)
class DistType:
    dims: tuple[DistDim, ...]

    def __str__(self) -> str:
        return "[" + ", ".join(str(d) for d in self.dims) + "]"

    @property
    def rank(self) -> int:
        return len(self.dims)

    def axes(self) -> tuple[str, ...]:
        return tuple(a for d in self.dims for a in d.axes)

    def localtype(self) -> tuple[int, ...]:
        return tuple(d.tile for d in self.dims)

    def globaltype(self) -> tuple[int, ...]:
        return tuple(d.global_ for d in self.dims)

    def localsize(self) -> int:
        return math.prod(self.localtype())

    def globalsize(self) -> int:
        return math.prod(self.globaltype())


def dim(tile: int, axes: Sequence[str] = (), global_: int | None = None) -> DistDim:
    if global_ is None:
        global_ = tile
    return DistDim(tile, tuple(axes), global_)


def dtype_of(dims: Sequence[DistDim]) -> DistType:
    return DistType(tuple(dims))


# ---------------------------------------------------------------------------
# Well-formedness (Fig. 7b)
# ---------------------------------------------------------------------------


def check_wf(t: DistType, mesh: Mesh) -> None:
    """WF-Type: axes valid + used affinely; sizes multiply out."""
    seen: set[str] = set()
    for i, d in enumerate(t.dims):
        prod = d.tile
        for a in d.axes:
            if a not in mesh:
                raise TypingError(f"dim {i}: unknown axis {a!r} in {t}")
            if a in seen:
                raise TypingError(f"axis {a!r} used more than once in {t}")
            seen.add(a)
            prod *= mesh.size(a)
        if prod != d.global_:
            raise TypingError(
                f"dim {i}: tile {d.tile} * axes {d.axes} != global "
                f"{d.global_} in {t}")
        if d.tile < 1 or d.global_ < 1:
            raise TypingError(f"dim {i}: non-positive sizes in {t}")


def is_wf(t: DistType, mesh: Mesh) -> bool:
    try:
        check_wf(t, mesh)
        return True
    except TypingError:
        return False


def valid_redistribution(t1: DistType, t2: DistType, mesh: Mesh) -> bool:
    """§2.5: a redistribution τ1 ⤳ τ2 is valid iff globaltypes agree."""
    return (is_wf(t1, mesh) and is_wf(t2, mesh)
            and t1.globaltype() == t2.globaltype())


# ---------------------------------------------------------------------------
# Parsing:  "[8{x,y}256, 1024]"  (tests & docs convenience)
# ---------------------------------------------------------------------------

_DIM_RE = re.compile(
    r"^\s*(?:(\d+)\s*\{([^}]*)\}\s*(\d+)|(\d+))\s*$")


def parse_type(s: str) -> DistType:
    s = s.strip()
    if not (s.startswith("[") and s.endswith("]")):
        raise TypingError(f"bad type syntax: {s!r}")
    body = s[1:-1].strip()
    dims: list[DistDim] = []
    if body:
        # split on commas not inside braces
        parts, depth, cur = [], 0, []
        for ch in body:
            if ch == "{":
                depth += 1
            elif ch == "}":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        parts.append("".join(cur))
        for p in parts:
            m = _DIM_RE.match(p)
            if not m:
                raise TypingError(f"bad dim syntax: {p!r}")
            if m.group(4) is not None:
                n = int(m.group(4))
                dims.append(DistDim(n, (), n))
            else:
                tile, axes_s, glob = int(m.group(1)), m.group(2), int(m.group(3))
                axes = tuple(a.strip() for a in axes_s.split(",") if a.strip())
                dims.append(DistDim(tile, axes, glob))
    return DistType(tuple(dims))


def decompose_type(t: DistType, mesh: Mesh) -> DistType:
    """Rewrite ``t`` over the prime-decomposed mesh of ``mesh``.

    An axis x of size 12 = 2*2*3 partitioning a dimension is replaced by its
    sub-axes ``x@0,x@1,x@2`` (minor-to-major) in the same position, which
    preserves the base offset map exactly (same mixed-radix split).
    """
    _, submap = mesh.decompose_primes()
    dims = []
    for d in t.dims:
        axes: list[str] = []
        for a in d.axes:
            axes.extend(submap[a])
        dims.append(DistDim(d.tile, tuple(axes), d.global_))
    return DistType(tuple(dims))
