"""Collective operations and their typing rules (paper Fig. 8, §7.1).

Ops are *syntactic*; ``apply(op, τ, mesh)`` implements the typing rules
T-AllGather / T-DynSlice / T-AllToAll / T-Permute, generalized to multiple
axes (§7.1).  Axis lists are minor-to-major, matching distributed types.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Union

from .dist_types import DistDim, DistType, Mesh, TypingError, check_wf


@dataclasses.dataclass(frozen=True)
class AllGather:
    """Remove the ``len(axes)`` minor-most axes of dimension ``dim``."""
    dim: int
    axes: tuple[str, ...] = ()   # if empty: remove the single minor-most axis

    def __str__(self):
        return f"allgather({self.dim}{',' + ','.join(self.axes) if self.axes else ''})"


@dataclasses.dataclass(frozen=True)
class DynSlice:
    """Introduce ``axes`` as new minor-most axes of dimension ``dim``."""
    dim: int
    axes: tuple[str, ...]

    def __str__(self):
        return f"dynslice({self.dim},{','.join(self.axes)})"


@dataclasses.dataclass(frozen=True)
class AllToAll:
    """Move the minor-most axes of dim ``src`` to minor-most of dim ``dst``."""
    src: int
    dst: int
    axes: tuple[str, ...] = ()

    def __str__(self):
        ax = (',' + ','.join(self.axes)) if self.axes else ''
        return f"alltoall({self.src},{self.dst}{ax})"


@dataclasses.dataclass(frozen=True)
class AllPermute:
    """Reassign tiles to devices; target type must share local+global type."""
    target: DistType

    def __str__(self):
        return f"allpermute(-> {self.target})"


Collective = Union[AllGather, DynSlice, AllToAll, AllPermute]


def _axes_product(axes: tuple[str, ...], mesh: Mesh) -> int:
    return math.prod(mesh.size(a) for a in axes)


def apply(op: Collective, t: DistType, mesh: Mesh) -> DistType:
    """Apply a typing rule; raises TypingError when preconditions fail."""
    check_wf(t, mesh)

    if isinstance(op, AllGather):
        d = _get_dim(t, op.dim)
        axes = op.axes or d.axes[:1]
        if not axes:
            raise TypingError(f"allgather on unpartitioned dim {op.dim} of {t}")
        if d.axes[:len(axes)] != tuple(axes):
            raise TypingError(
                f"allgather axes {axes} are not the minor-most axes of "
                f"dim {op.dim} in {t}")
        n = _axes_product(tuple(axes), mesh)
        new = DistDim(d.tile * n, d.axes[len(axes):], d.global_)
        out = _set_dim(t, op.dim, new)

    elif isinstance(op, DynSlice):
        d = _get_dim(t, op.dim)
        if not op.axes:
            raise TypingError("dynslice needs at least one axis")
        n = _axes_product(op.axes, mesh)
        if d.tile % n != 0:
            raise TypingError(
                f"dynslice: tile {d.tile} of dim {op.dim} not divisible by "
                f"{n} in {t}")
        used = set(t.axes())
        for a in op.axes:
            if a not in mesh:
                raise TypingError(f"dynslice: unknown axis {a!r}")
            if a in used:
                raise TypingError(f"dynslice: axis {a!r} already used in {t}")
        new = DistDim(d.tile // n, tuple(op.axes) + d.axes, d.global_)
        out = _set_dim(t, op.dim, new)

    elif isinstance(op, AllToAll):
        if op.src == op.dst:
            raise TypingError("alltoall requires distinct dimensions")
        ds = _get_dim(t, op.src)
        dd = _get_dim(t, op.dst)
        axes = op.axes or ds.axes[:1]
        if not axes:
            raise TypingError(f"alltoall from unpartitioned dim {op.src} of {t}")
        if ds.axes[:len(axes)] != tuple(axes):
            raise TypingError(
                f"alltoall axes {axes} are not the minor-most axes of dim "
                f"{op.src} in {t}")
        n = _axes_product(tuple(axes), mesh)
        if dd.tile % n != 0:
            raise TypingError(
                f"alltoall: tile {dd.tile} of dim {op.dst} not divisible by "
                f"{n} in {t}")
        new_src = DistDim(ds.tile * n, ds.axes[len(axes):], ds.global_)
        new_dst = DistDim(dd.tile // n, tuple(axes) + dd.axes, dd.global_)
        out = _set_dim(_set_dim(t, op.src, new_src), op.dst, new_dst)

    elif isinstance(op, AllPermute):
        if op.target.localtype() != t.localtype():
            raise TypingError(
                f"allpermute: local types differ: {t} vs {op.target}")
        if op.target.globaltype() != t.globaltype():
            raise TypingError(
                f"allpermute: global types differ: {t} vs {op.target}")
        out = op.target

    else:
        raise TypingError(f"unknown collective {op!r}")

    check_wf(out, mesh)
    return out


def apply_seq(ops, t: DistType, mesh: Mesh) -> list[DistType]:
    """Type a whole sequence; returns [τ0, τ1, ..., τn]."""
    types = [t]
    for op in ops:
        types.append(apply(op, types[-1], mesh))
    return types


def _get_dim(t: DistType, i: int) -> DistDim:
    if not (0 <= i < t.rank):
        raise TypingError(f"dimension {i} out of range for {t}")
    return t.dims[i]


def _set_dim(t: DistType, i: int, d: DistDim) -> DistType:
    dims = list(t.dims)
    dims[i] = d
    return DistType(tuple(dims))
