"""Baseline planner modelling the XLA SPMD partitioner's reshard heuristics.

The paper (§8, RQ2) compares against XLA's redistribution, described as
"carefully hand-crafted heuristics (that attempt, e.g., to synthesize
alltoall sequences or to detect cases directly implementable via
allpermute) with a fallback to allgather and dynslice (analogous to (2))".

We model that pipeline:
  A. identical types                      -> no-op
  B. identical local types                -> one allpermute
  C. single (multi-axis) alltoall pattern -> alltoall (+ final permute)
  D. per-dimension gather/slice when no axis moves across dimensions
  E. fallback: allgather everything, then dynslice everything
     (memory peak = the full global array — exactly what the paper's
     normal forms avoid).

Plans are returned as PhysicalPlans via the shared lowering utilities so
the interpreter / executor / cost model apply uniformly.
"""
from __future__ import annotations

import math

import numpy as np

from .dist_types import DistDim, DistType, Mesh, TypingError
from .lowering import _lower_alltoall, _lower_gather, _lower_slice, lower
from .offsets import base_offset_map, find_permutation
from .plan import PPermute, PhysicalPlan
from .weak import WeakOp


def plan_xla(t1: DistType, t2: DistType, mesh: Mesh) -> PhysicalPlan:
    if t1.globaltype() != t2.globaltype():
        raise TypingError("invalid redistribution")
    # Case A/B: permutation only (includes the identity).
    if t1.localtype() == t2.localtype():
        return _assemble([], t1, t2, mesh)
    # Case C: single alltoall.
    ops = _try_single_alltoall(t1, t2, mesh)
    if ops is not None:
        return _assemble(ops, t1, t2, mesh)
    # Case D: per-dimension gather/slice (no cross-dimension moves).
    ops = _try_dimwise(t1, t2, mesh)
    if ops is not None:
        return _assemble(ops, t1, t2, mesh)
    # Case E: full replication fallback.
    return _assemble(_fallback(t1, t2), t1, t2, mesh)


def _try_single_alltoall(t1, t2, mesh):
    lt1, lt2 = t1.localtype(), t2.localtype()
    for i in range(t1.rank):
        for j in range(t1.rank):
            if i == j:
                continue
            d = t1.dims[i]
            for k in range(1, len(d.axes) + 1):
                m = math.prod(mesh.size(a) for a in d.axes[:k])
                if lt2[i] == lt1[i] * m and lt2[j] * m == lt1[j] \
                        and lt1[j] % m == 0:
                    cand = list(lt1)
                    cand[i] *= m
                    cand[j] //= m
                    if tuple(cand) == tuple(lt2):
                        return [WeakOp("alltoall", i, m, j)]
    return None


def _try_dimwise(t1, t2, mesh):
    """Gather/slice each dim independently; None if axes cross dims."""
    gathers, slices = [], []
    for i, (d1, d2) in enumerate(zip(t1.dims, t2.dims)):
        if d1.tile == d2.tile:
            continue
        if d2.tile % d1.tile == 0:
            gathers.append(WeakOp("allgather", i, d2.tile // d1.tile))
        elif d1.tile % d2.tile == 0:
            slices.append(WeakOp("dynslice", i, d1.tile // d2.tile))
        else:
            return None
    # XLA's dim-wise path does not move axes across dimensions: require that
    # every axis released by a gather is not re-used by a slice elsewhere.
    released = set()
    for op in gathers:
        released.update(t1.dims[op.i].axes)
    needed = set()
    for op in slices:
        needed.update(a for a in t2.dims[op.i].axes
                      if a not in t1.dims[op.i].axes)
    if released & needed:
        return None
    # XLA orders gathers first (it materializes, then slices).
    return gathers + slices


def _fallback(t1, t2):
    """(2) in the paper: allgather every partitioned dim, then dynslice."""
    ops = []
    for i, d in enumerate(t1.dims):
        if d.tile != d.global_:
            ops.append(WeakOp("allgather", i, d.global_ // d.tile))
    for i, d in enumerate(t2.dims):
        if d.tile != d.global_:
            ops.append(WeakOp("dynslice", i, d.global_ // d.tile))
    return ops


def _assemble(weak_ops, t1, t2, mesh) -> PhysicalPlan:
    """Lower *in the given order* (no normal-form rewriting — the whole
    point of the baseline is that its fallback is NOT memory-efficient)."""
    n_dev = mesh.nelems
    beta = base_offset_map(t1, mesh).copy()
    beta2 = base_offset_map(t2, mesh)
    c = list(t1.localtype())
    ops = []
    for op in weak_ops:
        if op.kind == "dynslice":
            beta, phys = _lower_slice(op, beta, c, beta2, bias=True)
            c[op.i] //= op.m
        elif op.kind == "allgather":
            beta, phys = _lower_gather(op, beta, c)
            c[op.i] *= op.m
        elif op.kind == "alltoall":
            beta, phys = _lower_alltoall(op, beta, c)
            c[op.i] *= op.m
            c[op.j] //= op.m
        else:
            raise TypingError(op.kind)
        ops.append(phys)
    if not np.array_equal(beta, beta2):
        perm = find_permutation(beta, beta2)
        if not np.array_equal(perm, np.arange(n_dev)):
            ops.append(PPermute(tuple(int(x) for x in perm)))
    return PhysicalPlan(
        ops=ops, src_localtype=t1.localtype(), dst_localtype=t2.localtype(),
        globaltype=t1.globaltype(), n_devices=n_dev,
        beta_src=base_offset_map(t1, mesh), beta_dst=beta2)
