"""Execute physical redistribution plans with JAX collectives.

A PhysicalPlan addresses explicit devices; inside ``shard_map`` we realize
its ops with ``jax.lax`` collectives using ``axis_index_groups`` — the
portable equivalent of MPI communicators (and of XLA replica groups), which
is precisely how the paper's §6 device-map collectives become executable
without materializing any permutation:

  PGather   -> lax.all_gather(..., tiled=True, axis_index_groups=groups)
  PAllToAll -> lax.all_to_all(..., split_axis=dst, concat_axis=src, ...)
  PSlice    -> local lax.dynamic_slice_in_dim by a per-device chunk table
  PPermute  -> lax.ppermute with explicit (src, dst) pairs

Empirically verified semantics (see tests/test_jax_exec_multidevice.py):
  * all_gather concatenates tiles in the listed group order;
  * all_to_all: the device at rank k of its group receives every member's
    k-th split, concatenated in group order;
  * lax.axis_index over an axis tuple is the row-major linearized index.

Device-id convention: the linearized index over the mesh axis tuple in
mesh-declaration order — identical to ``repro.core.dist_types.Mesh`` ids.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh as JMesh
from jax.sharding import NamedSharding, PartitionSpec as P

from .dist_types import DistType, Mesh, TypingError
from .plan import PAllToAll, PGather, PPermute, PSlice, PhysicalPlan


def partition_spec(t: DistType) -> P:
    """DistType -> PartitionSpec.  Paper axis lists are minor-to-major;
    PartitionSpec lists major-to-minor, so each dim's axes are reversed."""
    entries = []
    for d in t.dims:
        if not d.axes:
            entries.append(None)
        elif len(d.axes) == 1:
            entries.append(d.axes[0])
        else:
            entries.append(tuple(reversed(d.axes)))
    return P(*entries)


def jax_mesh_of(mesh: Mesh, devices=None) -> JMesh:
    if devices is None:
        devices = jax.devices()
    shape = tuple(k for _, k in mesh.axes)
    arr = np.asarray(devices)[: mesh.nelems].reshape(shape)
    return JMesh(arr, mesh.names)


def plan_body(plan: PhysicalPlan, axis_names: tuple[str, ...]):
    """The shard_map body: local tile -> local tile, applying every op."""

    def body(tile):
        for op in plan.ops:
            if isinstance(op, PSlice):
                new_size = tile.shape[op.dim] // op.factor
                table = jnp.asarray(np.array(op.chunk_index, dtype=np.int32))
                k = table[jax.lax.axis_index(axis_names)]
                tile = jax.lax.dynamic_slice_in_dim(
                    tile, k * new_size, new_size, axis=op.dim)
            elif isinstance(op, PGather):
                tile = jax.lax.all_gather(
                    tile, axis_names, axis=op.dim, tiled=True,
                    axis_index_groups=[list(g) for g in op.groups])
            elif isinstance(op, PAllToAll):
                tile = jax.lax.all_to_all(
                    tile, axis_names, split_axis=op.dst, concat_axis=op.src,
                    tiled=True,
                    axis_index_groups=[list(g) for g in op.groups])
            elif isinstance(op, PPermute):
                perm = [(int(s), int(d)) for d, s in enumerate(op.src_for)]
                tile = jax.lax.ppermute(tile, axis_names, perm=perm)
            else:
                raise TypingError(f"unknown physical op {op!r}")
        return tile

    return body


def make_executor(plan: PhysicalPlan, t1: DistType, t2: DistType,
                  mesh: Mesh, jmesh: JMesh | None = None):
    """Build a jit-able function Array -> Array performing the plan."""
    jmesh = jmesh or jax_mesh_of(mesh)
    axis_names = tuple(mesh.names)
    in_spec = partition_spec(t1)
    out_spec = partition_spec(t2)
    body = plan_body(plan, axis_names)
    fn = jax.shard_map(body, mesh=jmesh, in_specs=in_spec,
                       out_specs=out_spec, check_vma=False)
    return fn, in_spec, out_spec


def redistribute_array(x: jax.Array, t1: DistType, t2: DistType, mesh: Mesh,
                       *, objective: str = "paper",
                       jmesh: JMesh | None = None) -> jax.Array:
    """Synthesize + execute a redistribution of a jax array.

    ``x`` must be (or will be placed as) sharded per ``t1`` over ``mesh``.
    """
    from .api import plan_redistribution
    r = plan_redistribution(t1, t2, mesh, objective=objective)
    jmesh = jmesh or jax_mesh_of(mesh)
    fn, in_spec, out_spec = make_executor(r.plan, t1, t2, mesh, jmesh)
    x = jax.device_put(x, NamedSharding(jmesh, in_spec))
    return jax.jit(fn, out_shardings=NamedSharding(jmesh, out_spec))(x)
