"""Semantics of distributed types: base offset maps (paper Fig. 7a, §4.1).

``D[[c{x,xs}n]](i) = c*i_x + D[[(c*k){xs}n]](i)`` — each axis in a dimension
contributes ``stride * coord`` where strides grow minor-to-major.

The *base offset map* ``T[[τ]]`` assigns to every mesh coordinate the base
offset tuple of the tile held by that device.  We materialize it as an
integer array of shape ``(n_devices, rank)`` in device-id order, which makes
device assignments (§6), equivalence checks (Def. 5.2/6.2), and permutation
synthesis straightforward.
"""
from __future__ import annotations

import numpy as np

from .dist_types import DistDim, DistType, Mesh, TypingError


def axis_strides(d: DistDim, mesh: Mesh) -> dict[str, int]:
    """Stride (in global elements along this dim) of each axis of ``d``."""
    out: dict[str, int] = {}
    c = d.tile
    for a in d.axes:
        out[a] = c
        c *= mesh.size(a)
    if c != d.global_:
        raise TypingError(f"dim {d} does not tile its global size")
    return out


def dim_offset(d: DistDim, mesh: Mesh, coord: dict[str, int]) -> int:
    """D[[d]] at a device coordinate (coord maps axis name -> index)."""
    off = 0
    for a, s in axis_strides(d, mesh).items():
        off += s * coord[a]
    return off


def base_offset_map(t: DistType, mesh: Mesh) -> np.ndarray:
    """T[[τ]] as an ``(n_devices, rank)`` int array in device-id order."""
    n = mesh.nelems
    out = np.zeros((n, t.rank), dtype=np.int64)
    # Vectorized: for each axis, add stride * coord over the raveled mesh.
    names = mesh.names
    sizes = np.array([mesh.size(a) for a in names], dtype=np.int64)
    # coordinate of every device along every mesh axis
    coords = np.stack(
        np.unravel_index(np.arange(n), tuple(sizes)), axis=1)  # (n, n_axes)
    for j, d in enumerate(t.dims):
        for a, s in axis_strides(d, mesh).items():
            ai = names.index(a)
            out[:, j] += s * coords[:, ai]
    return out


def equivalent(beta1: np.ndarray, beta2: np.ndarray) -> bool:
    """Def. 5.2: β1 ~ β2 iff related by a device permutation.

    Because base offset maps of well-formed types hit every tile the same
    number of times, this is equivalent to equality as multisets of rows.
    """
    if beta1.shape != beta2.shape:
        return False
    a = beta1[np.lexsort(beta1.T[::-1])]
    b = beta2[np.lexsort(beta2.T[::-1])]
    return bool(np.array_equal(a, b))


def find_permutation(beta_src: np.ndarray, beta_dst: np.ndarray) -> np.ndarray:
    """Find π with ``beta_dst[d] == beta_src[π[d]]`` (data for device d comes
    from device π[d]).  Raises if the maps are not equivalent.

    When tiles are replicated the matching is greedy with a preference for
    the identity (devices keep their own tile when possible) — this is what
    makes the final allpermute of Thm 6.7 vanish in the common case.
    """
    n = beta_src.shape[0]
    if not equivalent(beta_src, beta_dst):
        raise TypingError("base offset maps are not permutation-equivalent")
    key_src: dict[tuple, list[int]] = {}
    for i in range(n):
        key_src.setdefault(tuple(beta_src[i]), []).append(i)
    pi = np.full(n, -1, dtype=np.int64)
    # First pass: identity matches.
    for d in range(n):
        k = tuple(beta_dst[d])
        lst = key_src.get(k, [])
        if d in lst:
            lst.remove(d)
            pi[d] = d
    # Second pass: arbitrary assignment for the rest.
    for d in range(n):
        if pi[d] < 0:
            k = tuple(beta_dst[d])
            pi[d] = key_src[k].pop()
    return pi


def tile_of(global_arr: np.ndarray, offsets, local_shape) -> np.ndarray:
    """Slice the tile with the given base offsets out of a global array."""
    slices = tuple(slice(o, o + c) for o, c in zip(offsets, local_shape))
    return global_arr[slices]


def assemble_global(tiles: dict[int, np.ndarray], beta: np.ndarray,
                    global_shape) -> np.ndarray:
    """Reassemble (and cross-check) the global array from per-device tiles."""
    out = np.full(global_shape, np.nan)
    filled = np.zeros(global_shape, dtype=bool)
    for dev, tile in tiles.items():
        offs = beta[dev]
        slices = tuple(slice(int(o), int(o) + s)
                       for o, s in zip(offs, tile.shape))
        region = out[slices]
        if filled[slices].any():
            if not np.array_equal(region, tile):
                raise AssertionError(
                    f"inconsistent replicated tiles at device {dev}")
        out[slices] = tile
        filled[slices] = True
    if not filled.all():
        raise AssertionError("tiles do not cover the global array")
    return out
