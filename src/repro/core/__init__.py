"""Core: memory-efficient array redistribution via portable collectives."""

from .api import Redistribution, plan_redistribution, plan_xla_baseline
from .collectives import AllGather, AllPermute, AllToAll, DynSlice, apply, apply_seq
from .costmodel import HardwareModel, V5E, step_cost
from .dist_types import (DistDim, DistType, Mesh, TypingError, decompose_type,
                         dim, dtype_of, is_wf, check_wf, parse_type,
                         prime_factors, valid_redistribution)
from .interp import run_plan, shard, verify_plan
from .lowering import lower
from .normal_form import is_normal_form, normalize
from .offsets import base_offset_map, equivalent, find_permutation
from .plan import PAllToAll, PGather, PPermute, PSlice, PhysicalPlan
from .search import SearchError, SearchResult, synthesize
from .weak import WeakOp, mesh_prime_pool, plan_cost, plan_height
from .xla_baseline import plan_xla

__all__ = [
    "Redistribution", "plan_redistribution", "plan_xla_baseline",
    "AllGather", "AllPermute", "AllToAll", "DynSlice", "apply", "apply_seq",
    "HardwareModel", "V5E", "step_cost",
    "DistDim", "DistType", "Mesh", "TypingError", "decompose_type", "dim",
    "dtype_of", "is_wf", "check_wf", "parse_type", "prime_factors",
    "valid_redistribution",
    "run_plan", "shard", "verify_plan", "lower",
    "is_normal_form", "normalize",
    "base_offset_map", "equivalent", "find_permutation",
    "PAllToAll", "PGather", "PPermute", "PSlice", "PhysicalPlan",
    "SearchError", "SearchResult", "synthesize",
    "WeakOp", "mesh_prime_pool", "plan_cost", "plan_height", "plan_xla",
]
