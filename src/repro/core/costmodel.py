"""Cost models.

``paper_cost`` — Fig. 11: per-device data transfer counts (elements).
  allpermute : localsize(in)     alltoall : localsize(in)
  allgather  : localsize(out)    dynslice : 0

``HardwareModel`` — beyond-paper (the paper's own "future work", §8/§9):
adds per-collective latency and link bandwidth so that plan *time* can be
estimated; with a hierarchical mesh, per-axis bandwidths model intra- vs
inter-pod links.  The latency-aware search fixes the paper's Fig. 13
slowdowns on small transfers; the hierarchy-aware cost prefers plans that
keep traffic inside a pod.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .dist_types import Mesh

# ---------------------------------------------------------------------------
# Paper cost model (Fig. 11), on weak plans
# ---------------------------------------------------------------------------


def step_cost(kind: str, localsize_in: int, localsize_out: int) -> int:
    if kind == "dynslice":
        return 0
    if kind == "allgather":
        return localsize_out
    if kind in ("alltoall", "allpermute"):
        return localsize_in
    raise ValueError(f"unknown op kind {kind!r}")


# ---------------------------------------------------------------------------
# Hardware time model (beyond paper)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Per-device time estimate for a collective step.

    ``link_bw_bytes``: bytes/s of the slowest link crossed by the step.
    ``latency_s``: per-collective launch/sync latency (global barrier).
    ``elem_bytes``: bytes per array element.
    TPU v5e defaults: ~50 GB/s/link ICI, a few microseconds dispatch.
    """

    link_bw_bytes: float = 50e9
    latency_s: float = 8e-6
    elem_bytes: int = 4
    # Optional per-mesh-axis bandwidth override (e.g. {"pod": 5e9}) for
    # hierarchical topologies: a step touching a slow axis pays its bw.
    axis_bw: dict | None = None

    def bw_for_axes(self, axes: Sequence[str] | None) -> float:
        if not self.axis_bw or not axes:
            return self.link_bw_bytes
        return min(self.axis_bw.get(a, self.link_bw_bytes) for a in axes)

    def step_time(self, kind: str, localsize_in: int, localsize_out: int,
                  axes: Sequence[str] | None = None) -> float:
        elems = step_cost(kind, localsize_in, localsize_out)
        if kind == "dynslice":
            return 0.0  # purely local
        return self.latency_s + elems * self.elem_bytes / self.bw_for_axes(axes)

    def plan_time(self, steps) -> float:
        """steps: iterable of (kind, localsize_in, localsize_out, axes)."""
        return sum(self.step_time(*s) for s in steps)


V5E = HardwareModel(link_bw_bytes=50e9, latency_s=8e-6, elem_bytes=4)

# Hardware constants used throughout the roofline analysis (task spec).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
