"""Shortest-path synthesis of redistribution plans (paper §7.2).

Dijkstra over weak types (= localtypes with fixed globaltype).  Edges are
multi-axis weak collectives; weights follow the Fig. 11 cost model (or,
beyond the paper, a latency/bandwidth-aware time model — the paper's own
suggested future work, fixing its Fig. 13 small-transfer slowdowns).

The node set is restricted to localtypes whose localsize does not exceed
``max(localsize(τ1), localsize(τ2))`` — so *every* returned plan solves the
memory-constrained redistribution problem by construction.  Zero-cost
dynslice edges give over-partitioning (§7.2) for free.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import Counter

from .costmodel import HardwareModel, step_cost
from .dist_types import DistType, Mesh, TypingError, prime_factors
from .weak import WeakOp, divisors, fits, free_primes, mesh_prime_pool


class SearchError(Exception):
    pass


@dataclasses.dataclass
class SearchResult:
    ops: list[WeakOp]
    cost: int                # paper cost (elements transferred per device)
    time: float              # hardware-model time (if used; else 0.0)
    nodes_expanded: int
    height: int              # max localsize along the plan


def synthesize(t1: DistType, t2: DistType, mesh: Mesh, *,
               objective: str = "paper",
               hw: HardwareModel | None = None,
               memory_factor: float = 1.0,
               max_nodes: int = 500_000) -> SearchResult:
    """Find a (near-)optimal weak plan from τ1 to τ2.

    objective:
      "paper" — minimize Fig. 11 transfer cost (tie-break: fewer ops).
      "time"  — minimize HardwareModel time (latency-aware; beyond paper).
    memory_factor: scales the memory bound (1.0 = the paper's bound); the
      paper's §8 mentions trading memory for run-time as future work.
    """
    if t1.globaltype() != t2.globaltype():
        raise TypingError(
            f"invalid redistribution: globaltypes differ "
            f"{t1.globaltype()} vs {t2.globaltype()}")
    globaltype = t1.globaltype()
    pool = mesh_prime_pool(mesh)
    src = t1.localtype()
    dst = t2.localtype()
    # Validate both endpoints use only mesh primes.
    free_primes(src, globaltype, pool)
    free_primes(dst, globaltype, pool)

    bound = int(max(math.prod(src), math.prod(dst)) * memory_factor)
    hw = hw or HardwareModel()
    use_time = objective == "time"

    def edge_weight(kind, lin, lout):
        if use_time:
            return hw.step_time(kind, lin, lout)
        return step_cost(kind, lin, lout)

    # Dijkstra.  Entries: (weight, n_ops, tiebreak, node)
    start = tuple(src)
    goal = tuple(dst)
    dist: dict[tuple, float] = {start: 0.0}
    nops: dict[tuple, int] = {start: 0}
    parent: dict[tuple, tuple] = {}   # node -> (prev_node, op)
    pq: list = [(0.0, 0, start)]
    expanded = 0
    seen: set[tuple] = set()

    while pq:
        w, k, node = heapq.heappop(pq)
        if node in seen:
            continue
        seen.add(node)
        expanded += 1
        if expanded > max_nodes:
            raise SearchError(f"search exceeded {max_nodes} nodes")
        if node == goal:
            ops: list[WeakOp] = []
            cur = node
            while cur != start:
                prev, op = parent[cur]
                ops.append(op)
                cur = prev
            ops.reverse()
            from .weak import plan_cost, plan_height
            return SearchResult(
                ops=ops,
                cost=plan_cost(ops, start, globaltype, pool),
                time=_plan_time(ops, start, globaltype, pool, hw) if use_time else 0.0,
                nodes_expanded=expanded,
                height=plan_height(ops, start, globaltype, pool),
            )
        lsize = math.prod(node)
        free = free_primes(node, globaltype, pool)
        for op, nxt in _edges(node, globaltype, free, bound):
            ew = edge_weight(op.kind, lsize, math.prod(nxt))
            nw = w + ew
            nk = k + 1
            if nxt not in dist or (nw, nk) < (dist[nxt], nops.get(nxt, 1 << 60)):
                dist[nxt] = nw
                nops[nxt] = nk
                parent[nxt] = (node, op)
                heapq.heappush(pq, (nw, nk, nxt))

    raise SearchError(f"no plan found from {t1} to {t2} (bound={bound})")


def _edges(node, globaltype, free: Counter, bound: int):
    """Enumerate weak edges from a localtype node."""
    r = len(node)
    lsize = math.prod(node)
    free_prod = 1
    for p, cnt in free.items():
        free_prod *= p ** cnt
    for i in range(r):
        c_i = node[i]
        q_i = globaltype[i] // c_i
        # allgather(i, m): m | q_i
        for m in divisors(q_i):
            if m <= 1:
                continue
            if lsize * m <= bound:
                nxt = node[:i] + (c_i * m,) + node[i + 1:]
                yield WeakOp("allgather", i, m), nxt
        # dynslice(i, m): m | c_i, primes(m) within free pool
        for m in divisors(math.gcd(c_i, free_prod)):
            if m <= 1 or not fits(m, free):
                continue
            nxt = node[:i] + (c_i // m,) + node[i + 1:]
            yield WeakOp("dynslice", i, m), nxt
        # alltoall(i, j, m): m | q_i and m | c_j
        if q_i > 1:
            for j in range(r):
                if j == i:
                    continue
                for m in divisors(math.gcd(q_i, node[j])):
                    if m <= 1:
                        continue
                    nxt = list(node)
                    nxt[i] = c_i * m
                    nxt[j] = node[j] // m
                    yield WeakOp("alltoall", i, m, j), tuple(nxt)


def _plan_time(ops, c0, globaltype, pool, hw: HardwareModel) -> float:
    from .weak import weak_apply_seq
    types = weak_apply_seq(ops, c0, globaltype, pool)
    t = 0.0
    for op, cin, cout in zip(ops, types[:-1], types[1:]):
        t += hw.step_time(op.kind, math.prod(cin), math.prod(cout))
    return t
