"""Normal forms for sequences of collectives (paper §4.3, §5).

A sequence is in *normal form* when its ops match
``dynslice* {alltoall|allpermute}* allgather*`` (Def. 4.5).  Normal forms
solve the memory-constrained redistribution problem: localsize only falls
during the dynslice prefix, stays flat in the middle, and only rises during
the allgather suffix.

``normalize`` implements the constructive proof of Thm 4.8 on *weak* plans
(no allpermute — §5 makes the lemmas' case analyses permutation-free):
ops are exploded into prime-factor steps, adjacent out-of-order pairs are
rewritten per Lemmas 4.6/4.7 (which may *merge or cancel* ops, never
increasing Fig. 11 cost — Lemma 6.5), and finally adjacent same-kind ops
are re-merged (§7.1).
"""
from __future__ import annotations

import math
import re
from collections import Counter

from .dist_types import TypingError, prime_factors
from .weak import WeakOp, plan_cost, weak_apply, weak_apply_seq

_NF_RE = re.compile(r"^(d)*(t|p)*(g)*$")
_KIND_CODE = {"dynslice": "d", "alltoall": "t", "allpermute": "p",
              "allgather": "g"}
_RANK = {"dynslice": 0, "alltoall": 1, "allpermute": 1, "allgather": 2}


def is_normal_form(kinds) -> bool:
    return bool(_NF_RE.match("".join(_KIND_CODE[k] for k in kinds)))


def explode_primes(ops: list[WeakOp]) -> list[WeakOp]:
    """Split every multi-axis op into single-prime steps (Principle 1)."""
    out: list[WeakOp] = []
    for op in ops:
        for p in prime_factors(op.m):
            out.append(WeakOp(op.kind, op.i, p, op.j))
    return out


def merge_adjacent(ops: list[WeakOp]) -> list[WeakOp]:
    """§7.1 — merge adjacent same-kind ops on the same dimension(s)."""
    out: list[WeakOp] = []
    for op in ops:
        if out and out[-1].kind == op.kind and out[-1].i == op.i \
                and out[-1].j == op.j:
            out[-1] = WeakOp(op.kind, op.i, out[-1].m * op.m, op.j)
        else:
            out.append(op)
    return out


def _rewrite_pair(a: WeakOp, b: WeakOp) -> list[WeakOp] | None:
    """Rewrite an adjacent out-of-order pair (a before b, rank(a)>rank(b)).

    Returns the replacement list, or None if (a, b) is already in order.
    All cases follow the weak versions of Lemmas 4.6/4.7 with prime m.
    """
    ra, rb = _RANK[a.kind], _RANK[b.kind]
    if ra <= rb:
        return None
    p, q = a.m, b.m
    if a.kind == "allgather" and b.kind == "dynslice":
        # Peak Lemma 4.6 (weak): gather(i,p) ; slice(j,q)
        if a.i == b.i and p == q:
            return []                                    # case (1): cancel
        if a.i != b.i and p == q:
            return [WeakOp("alltoall", a.i, p, b.i)]     # case (3): fuse
        return [b, a]                                    # cases (2)/(4): swap
    if a.kind == "allgather" and b.kind == "alltoall":
        # Rising edge Lemma 4.7: gather(i,p) ; alltoall(k->l,q)
        if a.i == b.j and p == q:
            return [WeakOp("allgather", b.i, p)]         # merge into one gather
        return [b, a]                                    # commute / reassociate
    if a.kind == "alltoall" and b.kind == "dynslice":
        # Falling edge Lemma 4.7 (dual): alltoall(k->l,p) ; slice(i,q)
        if b.i == a.i and p == q:
            return [WeakOp("dynslice", a.j, p)]          # net effect: slice dst
        return [b, a]
    raise AssertionError(f"unexpected pair {a} ; {b}")


def normalize(ops: list[WeakOp], c0, globaltype, pool: Counter,
              max_steps: int = 100_000) -> list[WeakOp]:
    """Thm 4.8 (weak): rewrite any weak plan into normal form.

    The result is type-correct from ``c0``, reaches the same weak endpoint,
    and never costs more than the input plan (Lemma 6.5).
    """
    seq = explode_primes(ops)
    end = weak_apply_seq(ops, c0, globaltype, pool)[-1]
    steps = 0
    changed = True
    while changed:
        changed = False
        for idx in range(len(seq) - 1):
            repl = _rewrite_pair(seq[idx], seq[idx + 1])
            if repl is not None:
                seq = seq[:idx] + repl + seq[idx + 2:]
                changed = True
                steps += 1
                if steps > max_steps:
                    raise TypingError("normalization did not terminate")
                break
    # Validate the rewritten plan end-to-end.
    got = weak_apply_seq(seq, c0, globaltype, pool)[-1]
    if got != end:
        raise TypingError(
            f"normalization changed the endpoint: {got} != {end}")
    if not is_normal_form([op.kind for op in seq]):
        raise TypingError(f"normalization failed: {[str(o) for o in seq]}")
    return merge_adjacent(seq)


def assert_cost_nonincreasing(before: list[WeakOp], after: list[WeakOp],
                              c0, globaltype, pool: Counter) -> None:
    cb = plan_cost(before, c0, globaltype, pool)
    ca = plan_cost(after, c0, globaltype, pool)
    if ca > cb:
        raise AssertionError(f"normalization increased cost {cb} -> {ca}")
