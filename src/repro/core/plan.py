"""Physical redistribution plans (paper §6 — low-level MPI-style collectives).

A physical op addresses *explicit devices* ("ranks"), which is how the
paper's device maps ⟨φ, β⟩ are realized on a fixed SPMD mesh: instead of
permuting data so an axis is minor-most, collectives run over explicit
device groups (MPI communicators / XLA replica groups /
``jax.lax.*(axis_index_groups=...)``) and the bookkeeping of *which logical
axis that was* lives in the evolving device assignment β.

Ops:
  PSlice(dim, factor, chunk_index) — local dynamic-slice; device d keeps
      chunk ``chunk_index[d]`` of its tile along ``dim``.
  PGather(dim, groups)             — all-gather; each group lists the devices
      holding the chunks of one output tile, ascending by base offset.
  PAllToAll(src, dst, groups)      — all-to-all moving partitioning from dim
      ``src`` (gathered) to dim ``dst`` (split m ways).
  PPermute(src_for)                — tile permutation; device d receives the
      tile of device ``src_for[d]``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class PSlice:
    dim: int
    factor: int
    chunk_index: tuple[int, ...]   # per-device chunk choice, len = n_devices

    def describe(self) -> str:
        return f"pslice(dim={self.dim}, m={self.factor})"


@dataclasses.dataclass(frozen=True)
class PGather:
    dim: int
    groups: tuple[tuple[int, ...], ...]

    @property
    def factor(self) -> int:
        return len(self.groups[0])

    def describe(self) -> str:
        return f"pgather(dim={self.dim}, m={self.factor}, groups={len(self.groups)})"


@dataclasses.dataclass(frozen=True)
class PAllToAll:
    src: int
    dst: int
    groups: tuple[tuple[int, ...], ...]

    @property
    def factor(self) -> int:
        return len(self.groups[0])

    def describe(self) -> str:
        return (f"palltoall({self.src}->{self.dst}, m={self.factor}, "
                f"groups={len(self.groups)})")


@dataclasses.dataclass(frozen=True)
class PPermute:
    src_for: tuple[int, ...]       # device d's new tile comes from src_for[d]

    def describe(self) -> str:
        moved = sum(1 for d, s in enumerate(self.src_for) if d != s)
        return f"ppermute(moved={moved}/{len(self.src_for)})"


PhysOp = Union[PSlice, PGather, PAllToAll, PPermute]


@dataclasses.dataclass
class PhysicalPlan:
    """A fully lowered redistribution program."""
    ops: list            # list[PhysOp]
    src_localtype: tuple[int, ...]
    dst_localtype: tuple[int, ...]
    globaltype: tuple[int, ...]
    n_devices: int
    beta_src: np.ndarray   # (n_dev, rank) — T[[τ1]]
    beta_dst: np.ndarray   # (n_dev, rank) — T[[τ2]]

    def kinds(self) -> list[str]:
        names = {PSlice: "dynslice", PGather: "allgather",
                 PAllToAll: "alltoall", PPermute: "allpermute"}
        return [names[type(o)] for o in self.ops]

    def localtypes(self) -> list[tuple[int, ...]]:
        """Per-step localtypes τ0..τn (for height/cost accounting)."""
        cur = list(self.src_localtype)
        out = [tuple(cur)]
        for op in self.ops:
            if isinstance(op, PSlice):
                cur[op.dim] //= op.factor
            elif isinstance(op, PGather):
                cur[op.dim] *= op.factor
            elif isinstance(op, PAllToAll):
                cur[op.src] *= op.factor
                cur[op.dst] //= op.factor
            out.append(tuple(cur))
        return out

    def height(self) -> int:
        return max(math.prod(c) for c in self.localtypes())

    def cost(self) -> int:
        """Fig. 11 cost (elements per device)."""
        from .costmodel import step_cost
        total = 0
        lts = self.localtypes()
        for op, cin, cout in zip(self.ops, lts[:-1], lts[1:]):
            kind = {PSlice: "dynslice", PGather: "allgather",
                    PAllToAll: "alltoall", PPermute: "allpermute"}[type(op)]
            total += step_cost(kind, math.prod(cin), math.prod(cout))
        return total

    def n_permutes(self) -> int:
        return sum(isinstance(o, PPermute) for o in self.ops)

    def describe(self) -> str:
        return " ; ".join(op.describe() for op in self.ops) or "<identity>"
