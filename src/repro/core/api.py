"""Top-level planning API.

``plan_redistribution`` is the paper's full pipeline:
  prime-decompose the mesh (Principle 1) -> weak shortest-path search
  (§7.2) -> normal form (Thm 4.8) -> lowering with device maps and at most
  one hoisted permute (§6, §7.3) -> PhysicalPlan.

The physical plan addresses devices of the *original* mesh directly, so
prime decomposition never leaks into execution.
"""
from __future__ import annotations

import dataclasses

from .costmodel import HardwareModel
from .dist_types import DistType, Mesh, decompose_type, parse_type
from .lowering import lower
from .plan import PhysicalPlan
from .search import SearchResult, synthesize
from .xla_baseline import plan_xla


@dataclasses.dataclass
class Redistribution:
    plan: PhysicalPlan
    search: SearchResult
    t1: DistType
    t2: DistType
    mesh: Mesh


def plan_redistribution(t1: DistType | str, t2: DistType | str,
                        mesh: Mesh | dict, *,
                        objective: str = "paper",
                        hw: HardwareModel | None = None,
                        memory_factor: float = 1.0) -> Redistribution:
    if isinstance(mesh, dict):
        mesh = Mesh.make(mesh)
    if isinstance(t1, str):
        t1 = parse_type(t1)
    if isinstance(t2, str):
        t2 = parse_type(t2)

    dmesh, _ = mesh.decompose_primes()
    d1 = decompose_type(t1, mesh)
    d2 = decompose_type(t2, mesh)
    res = synthesize(d1, d2, dmesh, objective=objective, hw=hw,
                     memory_factor=memory_factor)
    # Lower over the ORIGINAL mesh: weak ops only mention factors, and the
    # base offset maps of τ and its decomposition are identical.
    plan = lower(res.ops, t1, t2, mesh)
    return Redistribution(plan=plan, search=res, t1=t1, t2=t2, mesh=mesh)


def plan_xla_baseline(t1: DistType | str, t2: DistType | str,
                      mesh: Mesh | dict) -> PhysicalPlan:
    if isinstance(mesh, dict):
        mesh = Mesh.make(mesh)
    if isinstance(t1, str):
        t1 = parse_type(t1)
    if isinstance(t2, str):
        t2 = parse_type(t2)
    return plan_xla(t1, t2, mesh)
