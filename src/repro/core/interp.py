"""Reference interpreter for physical plans.

Simulates every device's memory as a numpy tile and executes the plan's
collectives faithfully, tracking *per-device peak memory* so the paper's
memory guarantee (Thm 4.8 / §4.3) can be checked on every synthesized plan,
and *transferred elements* so the Fig. 11 cost model can be cross-checked.

This is the semantic oracle for both the formal layer and the JAX executor.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .dist_types import DistType, Mesh
from .offsets import base_offset_map, tile_of
from .plan import PAllToAll, PGather, PPermute, PSlice, PhysicalPlan


@dataclasses.dataclass
class InterpResult:
    tiles: dict                 # device id -> np.ndarray
    peak_elems: int             # max per-device elements held at any step
    transferred_elems: int      # total elements that crossed the network
    steps: int


def shard(global_arr: np.ndarray, t: DistType, mesh: Mesh) -> dict[int, np.ndarray]:
    """Initial placement: device d holds tile at T[[τ]](d)."""
    beta = base_offset_map(t, mesh)
    local = t.localtype()
    return {d: tile_of(global_arr, beta[d], local).copy()
            for d in range(mesh.nelems)}


def run_plan(plan: PhysicalPlan, tiles: dict[int, np.ndarray]) -> InterpResult:
    tiles = dict(tiles)
    n_dev = plan.n_devices
    peak = max(t.size for t in tiles.values())
    moved = 0
    for op in plan.ops:
        if isinstance(op, PSlice):
            newc = {d: None for d in tiles}
            for d in range(n_dev):
                t = tiles[d]
                m = op.factor
                size = t.shape[op.dim] // m
                k = op.chunk_index[d]
                sl = [slice(None)] * t.ndim
                sl[op.dim] = slice(k * size, (k + 1) * size)
                newc[d] = t[tuple(sl)].copy()
            tiles = newc

        elif isinstance(op, PGather):
            new = dict(tiles)
            for g in op.groups:
                gathered = np.concatenate([tiles[d] for d in g], axis=op.dim)
                for d in g:
                    new[d] = gathered.copy()
                # every member receives the other m-1 chunks
                moved += sum(tiles[e].size for e in g) * (len(g) - 1)
            tiles = new

        elif isinstance(op, PAllToAll):
            new = dict(tiles)
            for g in op.groups:
                m = len(g)
                splits = {d: np.array_split(tiles[d], m, axis=op.dst)
                          for d in g}
                for k, d in enumerate(g):
                    new[d] = np.concatenate(
                        [splits[e][k] for e in g], axis=op.src)
                    # d receives m-1 remote chunks
                    moved += sum(splits[e][k].size for e in g if e != d)
            tiles = new

        elif isinstance(op, PPermute):
            new = {}
            for d in range(n_dev):
                s = op.src_for[d]
                new[d] = tiles[s]
                if s != d:
                    moved += tiles[s].size
            tiles = {d: v.copy() for d, v in new.items()}

        else:
            raise TypeError(f"unknown physical op {op!r}")
        peak = max(peak, max(t.size for t in tiles.values()))
    return InterpResult(tiles=tiles, peak_elems=peak,
                        transferred_elems=moved, steps=len(plan.ops))


def verify_plan(plan: PhysicalPlan, t1: DistType, t2: DistType, mesh: Mesh,
                global_arr: np.ndarray | None = None) -> InterpResult:
    """Run the plan on a concrete array and check the result against the
    direct tiling of the global array by τ2.  Raises on any mismatch."""
    if global_arr is None:
        global_arr = np.arange(
            math.prod(t1.globaltype()), dtype=np.int64
        ).reshape(t1.globaltype())
    tiles = shard(global_arr, t1, mesh)
    res = run_plan(plan, tiles)
    beta2 = base_offset_map(t2, mesh)
    local2 = t2.localtype()
    for d in range(mesh.nelems):
        expect = tile_of(global_arr, beta2[d], local2)
        got = res.tiles[d]
        if got.shape != expect.shape or not np.array_equal(got, expect):
            raise AssertionError(
                f"device {d}: tile mismatch after plan "
                f"{plan.describe()}\n expected offsets {beta2[d]}")
    return res
