"""Lowering weak plans to physical plans (paper §6, Thm 6.4/6.7, §7.3).

Input: a *normal form* weak plan (dynslice* alltoall* allgather*) plus the
concrete endpoint types.  Output: a PhysicalPlan with at most ONE permute,
hoisted before the trailing allgather block (§7.3: permuting smaller tiles
is cheaper), and elided entirely when the device assignments line up.

The lowering maintains the explicit device assignment β (base offsets per
device) — the paper's ⟨φ, β⟩ — and exploits every degree of freedom to make
the final permutation the identity:

  * dynslice chunk choices are biased toward the target assignment
    (§7.3 optimization 2), subject to replica-quota validity;
  * the pre-gather assignment is obtained by pulling the target back
    through the gather suffix, greedily matching the current assignment
    (beyond-paper: this generalizes §7.3 and makes permutes vanish in the
    common case, not just for gather-free plans).
"""
from __future__ import annotations

import math
from collections import Counter, defaultdict

import numpy as np

from .dist_types import DistType, Mesh, TypingError
from .normal_form import is_normal_form, normalize
from .offsets import base_offset_map, find_permutation
from .plan import PAllToAll, PGather, PPermute, PSlice, PhysicalPlan
from .weak import WeakOp, mesh_prime_pool


def lower(weak_ops: list[WeakOp], t1: DistType, t2: DistType, mesh: Mesh,
          *, hoist_permute: bool = True, match_assignment: bool = True
          ) -> PhysicalPlan:
    """Lower a weak plan into a physical plan over explicit device ids."""
    pool = mesh_prime_pool(mesh)
    globaltype = t1.globaltype()
    if not is_normal_form([op.kind for op in weak_ops]):
        weak_ops = normalize(weak_ops, t1.localtype(), globaltype, pool)

    n_dev = mesh.nelems
    beta = base_offset_map(t1, mesh).copy()
    beta2 = base_offset_map(t2, mesh)
    c = list(t1.localtype())
    ops: list = []

    slices = [op for op in weak_ops if op.kind == "dynslice"]
    a2as = [op for op in weak_ops if op.kind == "alltoall"]
    gathers = [op for op in weak_ops if op.kind == "allgather"]

    # ---- dynslice prefix (local, zero transfer) -------------------------
    for op in slices:
        beta, phys = _lower_slice(op, beta, c, beta2,
                                  bias=match_assignment)
        c[op.i] //= op.m
        ops.append(phys)

    # ---- alltoall middle ------------------------------------------------
    for op in a2as:
        beta, phys = _lower_alltoall(op, beta, c)
        c[op.i] *= op.m
        c[op.j] //= op.m
        ops.append(phys)

    # ---- hoisted permute + allgather suffix -----------------------------
    if gathers:
        beta_req = _pullback_target(gathers, beta, beta2, c,
                                    match_current=match_assignment)
        perm = find_permutation(beta, beta_req)
        if not np.array_equal(perm, np.arange(n_dev)):
            if hoist_permute:
                ops.append(PPermute(tuple(int(x) for x in perm)))
                beta = beta_req
            # else: fall through; a final permute is emitted below.
        else:
            beta = beta_req
        for op in gathers:
            beta, phys = _lower_gather(op, beta, c)
            c[op.i] *= op.m
            ops.append(phys)

    # ---- final safety permute (Thm 6.7 worst case) ----------------------
    if not np.array_equal(beta, beta2):
        perm = find_permutation(beta, beta2)
        if not np.array_equal(perm, np.arange(n_dev)):
            ops.append(PPermute(tuple(int(x) for x in perm)))
        beta = beta2

    plan = PhysicalPlan(
        ops=ops,
        src_localtype=t1.localtype(),
        dst_localtype=t2.localtype(),
        globaltype=globaltype,
        n_devices=n_dev,
        beta_src=base_offset_map(t1, mesh),
        beta_dst=beta2,
    )
    if plan.n_permutes() > 1:
        raise TypingError(
            f"lowering produced {plan.n_permutes()} permutes (Thm 6.7 "
            f"guarantees at most one): {plan.describe()}")
    return plan


# ---------------------------------------------------------------------------
# Per-op lowering
# ---------------------------------------------------------------------------


def _replica_classes(beta: np.ndarray) -> dict[tuple, list[int]]:
    classes: dict[tuple, list[int]] = defaultdict(list)
    for d in range(beta.shape[0]):
        classes[tuple(beta[d])].append(d)
    return classes


def _lower_slice(op: WeakOp, beta: np.ndarray, c: list[int],
                 beta2: np.ndarray, bias: bool):
    """dynslice(i, m): every device keeps one of m chunks of dim i.

    Validity: within each replica class (devices holding identical tiles,
    class size R with m | R), each chunk must be kept by exactly R/m
    devices.  Preference: the chunk overlapping the device's target region.
    """
    i, m = op.i, op.m
    newc = c[i] // m
    n_dev = beta.shape[0]
    idx = np.full(n_dev, -1, dtype=np.int64)
    for _, devs in _replica_classes(beta).items():
        R = len(devs)
        if R % m:
            raise TypingError(
                f"dynslice({i},{m}): replica class of size {R} not divisible")
        quota = [R // m] * m
        leftover = []
        for d in devs:
            k = (int(beta2[d, i]) - int(beta[d, i])) // newc if bias else -1
            if 0 <= k < m and quota[k] > 0:
                idx[d] = k
                quota[k] -= 1
            else:
                leftover.append(d)
        ki = 0
        for d in leftover:
            while quota[ki] == 0:
                ki += 1
            idx[d] = ki
            quota[ki] -= 1
    new_beta = beta.copy()
    new_beta[:, i] += idx * newc
    return new_beta, PSlice(i, m, tuple(int(x) for x in idx))


def _lower_alltoall(op: WeakOp, beta: np.ndarray, c: list[int]):
    """alltoall(i->j, m): groups hold the m chunks of one dim-i block.

    Group order is ascending dim-i offset (required so the concatenation
    along dim i forms a contiguous tile); the device at rank k keeps the
    k-th split of dim j.  Replicas of the same tile land at the same rank
    in different groups and therefore stay replicas.
    """
    i, j, m = op.i, op.j, op.m
    block = c[i] * m
    newcj = c[j] // m
    # Class key: all offsets with dim i floored to its block.
    cls: dict[tuple, list[int]] = defaultdict(list)
    for d in range(beta.shape[0]):
        key = list(beta[d])
        key[i] = beta[d, i] // block
        cls[tuple(key)].append(d)
    groups = []
    for key, devs in sorted(cls.items()):
        # split by chunk rank within the block
        by_rank: dict[int, list[int]] = defaultdict(list)
        for d in devs:
            by_rank[int((beta[d, i] % block) // c[i])].append(d)
        R = len(by_rank[0])
        if any(len(v) != R for v in by_rank.values()) or len(by_rank) != m:
            raise TypingError(f"alltoall({i}->{j},{m}): ragged groups")
        for r in range(R):
            groups.append(tuple(by_rank[k][r] for k in range(m)))
    new_beta = beta.copy()
    for g in groups:
        for k, d in enumerate(g):
            new_beta[d, i] = (beta[d, i] // block) * block
            new_beta[d, j] = beta[d, j] + k * newcj
    return new_beta, PAllToAll(i, j, tuple(groups))


def _lower_gather(op: WeakOp, beta: np.ndarray, c: list[int]):
    """allgather(i, m): groups hold the m chunks of one output tile."""
    i, m = op.i, op.m
    block = c[i] * m
    cls: dict[tuple, list[int]] = defaultdict(list)
    for d in range(beta.shape[0]):
        key = list(beta[d])
        key[i] = beta[d, i] // block
        cls[tuple(key)].append(d)
    groups = []
    for key, devs in sorted(cls.items()):
        by_rank: dict[int, list[int]] = defaultdict(list)
        for d in devs:
            by_rank[int((beta[d, i] % block) // c[i])].append(d)
        R = len(by_rank.get(0, []))
        if len(by_rank) != m or any(len(v) != R for v in by_rank.values()):
            raise TypingError(f"allgather({i},{m}): ragged groups "
                              f"{dict((k, len(v)) for k, v in by_rank.items())}")
        for r in range(R):
            groups.append(tuple(by_rank[k][r] for k in range(m)))
    new_beta = beta.copy()
    new_beta[:, i] = (beta[:, i] // block) * block
    return new_beta, PGather(i, tuple(groups))


def _pullback_target(gathers: list[WeakOp], beta_cur: np.ndarray,
                     beta2: np.ndarray, c: list[int], match_current: bool
                     ) -> np.ndarray:
    """Pull the target assignment back through the gather suffix.

    Returns β_req: an assignment at pre-gather localtype such that running
    the gathers from β_req lands exactly on β2.  Each device's pre-gather
    tile must lie inside its target tile; chunk choices are matched
    greedily against the current assignment so the hoisted permute is the
    identity whenever possible.
    """
    n_dev = beta_cur.shape[0]
    rank = beta_cur.shape[1]
    # Total gather factor per dim.
    factor = [1] * rank
    for op in gathers:
        factor[op.i] *= op.m
    pre_tile = list(c)  # localtype before gathers

    # Quota: every pre-gather tile must be held by exactly R_pre devices.
    n_tiles_pre = 1
    for d in range(rank):
        # number of distinct tiles along dim d at pre-gather localtype
        n_tiles_pre *= _n_distinct(beta_cur[:, d], pre_tile[d])
    R_pre = n_dev // n_tiles_pre

    quota: Counter = Counter()
    for d in range(n_dev):
        for combo in _chunk_combos(beta2[d], factor, pre_tile):
            quota[combo] = R_pre
    beta_req = np.zeros_like(beta_cur)
    assigned: Counter = Counter()
    leftover = []
    for d in range(n_dev):
        cur = tuple(int(x) for x in beta_cur[d])
        if match_current and _inside(cur, beta2[d], factor, pre_tile) \
                and assigned[cur] < quota[cur]:
            beta_req[d] = cur
            assigned[cur] += 1
        else:
            leftover.append(d)
    for d in leftover:
        for combo in _chunk_combos(beta2[d], factor, pre_tile):
            if assigned[combo] < quota[combo]:
                beta_req[d] = combo
                assigned[combo] += 1
                break
        else:
            raise TypingError("pullback: no chunk quota left (invalid plan)")
    return beta_req


def _n_distinct(col: np.ndarray, tile: int) -> int:
    # Offsets are already tile-aligned; distinct offsets = distinct tiles.
    return len(np.unique(col))


def _inside(pre: tuple, tgt_row: np.ndarray, factor, pre_tile) -> bool:
    for dim, (o, t) in enumerate(zip(pre, tgt_row)):
        lo = int(t)
        hi = lo + pre_tile[dim] * factor[dim]
        if not (lo <= o < hi and (o - lo) % pre_tile[dim] == 0):
            return False
    return True


def _chunk_combos(tgt_row: np.ndarray, factor, pre_tile):
    """All pre-gather offset rows inside a target tile (row-major order)."""
    import itertools
    ranges = []
    for dim in range(len(pre_tile)):
        base = int(tgt_row[dim])
        ranges.append([base + k * pre_tile[dim] for k in range(factor[dim])])
    for combo in itertools.product(*ranges):
        yield tuple(combo)
