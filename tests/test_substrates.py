"""Substrate tests: data determinism, optimizer, compression, checkpoint/
restart, elastic reshard planning, trainer fault tolerance, serving."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.optim import compress
from repro.optim.adamw import AdamWConfig, apply_updates, init_state, schedule
from repro.checkpoint import ckpt
from repro.checkpoint.elastic import dist_type_of, reshard_plan
from repro.core import Mesh as CMesh
from repro.train.trainer import TrainConfig, train
from repro.serve.engine import Request, ServeEngine
from jax.sharding import PartitionSpec as P

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)


class TestData:
    def test_deterministic_and_shardable(self):
        data = SyntheticLM(TINY, DataConfig(global_batch=4, seq_len=16))
        g1 = data.global_batch(3)
        g2 = data.global_batch(3)
        np.testing.assert_array_equal(g1["tokens"], g2["tokens"])
        # shards tile the global batch exactly
        s0 = data.shard_batch(3, 0, 2)
        s1 = data.shard_batch(3, 1, 2)
        np.testing.assert_array_equal(
            np.concatenate([s0["tokens"], s1["tokens"]]), g1["tokens"])

    def test_labels_shifted(self):
        data = SyntheticLM(TINY, DataConfig(global_batch=2, seq_len=16))
        b = data.global_batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestOptim:
    def test_adamw_descends_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        state = init_state(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=1000, grad_clip=0)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = apply_updates(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(
            cfg.min_lr_ratio, rel=1e-3)

    def test_compression_error_feedback(self):
        # with error feedback, the *accumulated* dequantized signal tracks
        # the true accumulated gradient
        g = {"w": jnp.full((128,), 0.001)}
        err = compress.init_error(g)
        total = jnp.zeros((128,))
        for _ in range(50):
            deq, err = compress.apply(g, err)
            total = total + deq["w"]
        np.testing.assert_allclose(np.asarray(total), 0.05, rtol=0.15)

    def test_compression_bounded_error(self):
        key = jax.random.PRNGKey(0)
        g = {"w": jax.random.normal(key, (64, 64))}
        err = compress.init_error(g)
        deq, err2 = compress.apply(g, err)
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127
        assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= scale * 0.51


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        params = init_params(jax.random.PRNGKey(0), TINY)
        opt = init_state(params)
        ckpt.save(tmp_path, 7, (params, opt))
        (p2, o2), step = ckpt.restore(tmp_path, (params, opt))
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), b)

    def test_async_save(self, tmp_path):
        params = init_params(jax.random.PRNGKey(0), TINY)
        t = ckpt.save(tmp_path, 3, params, blocking=False)
        t.join()
        assert ckpt.latest_step(tmp_path) == 3


class TestElastic:
    def test_dist_type_of_roundtrip(self):
        mesh = CMesh.make({"data": 4, "model": 2})
        t = dist_type_of((64, 32), P("data", "model"), mesh)
        assert t.localtype() == (16, 16)
        t2 = dist_type_of((64, 32), P(("data", "model"),), mesh)
        assert t2.localtype() == (8, 32)
        # major-to-minor reversal: data is major
        assert t2.dims[0].axes == ("model", "data")

    def test_reshard_plan_beats_baseline(self):
        # TP-degree change: (data 4, model 2) -> (data 2, model 4) layouts
        mesh = CMesh.make({"data": 4, "model": 2})
        shapes = {"wq": (256, 128), "wo": (128, 256), "embed": (1024, 128)}
        old = {"wq": P(None, "model"), "wo": P("model", None),
               "embed": P(("data", "model"), None)}
        new = {"wq": P(None, ("data", "model")), "wo": P(("data", "model"),),
               "embed": P("model", "data")}
        plans, rep = reshard_plan(shapes, old, new, mesh)
        assert rep.n_replanned == 3
        assert rep.ours_peak_elems <= rep.xla_peak_elems
        assert rep.ours_cost_elems <= rep.xla_cost_elems
        # every per-leaf plan satisfies the paper's memory bound
        for name, plan in plans.items():
            assert plan.height() <= max(
                math.prod(plan.src_localtype), math.prod(plan.dst_localtype))


class TestTrainer:
    def test_loss_decreases(self, tmp_path):
        res = train(TINY, TrainConfig(steps=40, ckpt_dir=None),
                    DataConfig(global_batch=8, seq_len=32),
                    AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40))
        assert res.steps_run == 40
        first = np.mean(res.losses[:5])
        last = np.mean(res.losses[-5:])
        assert last < first - 0.1, (first, last)

    def test_checkpoint_restart_resumes_exactly(self, tmp_path):
        d = DataConfig(global_batch=4, seq_len=16)
        full = train(TINY, TrainConfig(steps=12, ckpt_every=6,
                                       ckpt_dir=None, seed=5), d)
        # crash after step 6 (simulated by only running 6 steps)
        train(TINY, TrainConfig(steps=6, ckpt_every=6,
                                ckpt_dir=str(tmp_path), seed=5,
                                async_ckpt=False), d)
        resumed = train(TINY, TrainConfig(steps=12, ckpt_every=6,
                                          ckpt_dir=str(tmp_path), seed=5,
                                          async_ckpt=False), d)
        assert resumed.restored_from == 6
        # CPU XLA reductions are not bitwise run-to-run deterministic;
        # resume-correctness is loss-trajectory equality to tight tolerance.
        np.testing.assert_allclose(resumed.losses, full.losses[6:],
                                   rtol=1e-2, atol=1e-3)

    def test_microbatching_matches_full_batch(self):
        d = DataConfig(global_batch=8, seq_len=16)
        one = train(TINY, TrainConfig(steps=3, microbatches=1, seed=2), d)
        four = train(TINY, TrainConfig(steps=3, microbatches=4, seed=2), d)
        np.testing.assert_allclose(one.losses, four.losses, rtol=2e-3)

    def test_grad_compression_trains(self):
        d = DataConfig(global_batch=8, seq_len=32)
        res = train(TINY, TrainConfig(steps=25, grad_compression=True), d)
        assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])


class TestServe:
    def test_batched_serving_drains(self):
        cfg = TINY
        params = init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
        reqs = [Request(rid=i, prompt=np.arange(3 + i) % cfg.vocab,
                        max_new_tokens=4) for i in range(5)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        for r in reqs:
            assert r.done and len(r.out_tokens) >= 4

    def test_batching_does_not_change_outputs(self):
        """Prefill logits for a slot must be independent of co-batched
        requests.  (Compared as logits with tolerance: greedy token chains
        of an untrained model diverge on argmax near-ties under CPU
        thread-order float nondeterminism.)"""
        cfg = TINY
        params = init_params(jax.random.PRNGKey(0), cfg)
        prompt = np.array([5, 9, 2])

        def prefill_logits(engine, slot, toks):
            logits = None
            for t, tok in enumerate(toks):
                tok_b = np.zeros((engine.slots, 1), np.int32)
                tok_b[slot, 0] = tok
                logits = engine._step_rows(tok_b, [slot])
                engine.pos[slot] += 1
            return np.asarray(logits[slot, 0], np.float32)

        eng1 = ServeEngine(cfg, params, batch_slots=2, max_len=64)
        l1 = prefill_logits(eng1, 0, prompt)
        eng2 = ServeEngine(cfg, params, batch_slots=2, max_len=64)
        # co-batched: another request occupies slot 1 first
        other = prefill_logits(eng2, 1, np.array([7, 7, 7, 7]))
        l2 = prefill_logits(eng2, 0, prompt)
        np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-5)
