"""Pallas kernel validation: interpret=True vs pure-jnp oracles, sweeping
shapes and dtypes (task spec c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


class TestTileRelayout:
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32"])
    @pytest.mark.parametrize("C,a,b", [(2, 4, 8), (4, 8, 128), (6, 2, 512),
                                       (3, 16, 100)])
    def test_matches_ref(self, C, a, b, dtype):
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(C * a, b)).astype(dtype)
        perm = tuple(rng.permutation(C).tolist())
        got = ops.tile_relayout(x, perm, interpret=True)
        want = ref.tile_relayout_ref(x, perm)
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 6), st.integers(1, 8), st.integers(1, 64),
           st.randoms())
    def test_property_random(self, C, a, b, rnd):
        perm = list(range(C))
        rnd.shuffle(perm)
        x = jnp.arange(C * a * b, dtype=jnp.float32).reshape(C * a, b)
        got = ops.tile_relayout(x, tuple(perm), interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.tile_relayout_ref(x, tuple(perm))))


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("B,H,KV,S,d", [
        (1, 2, 2, 128, 32), (2, 4, 2, 256, 64), (1, 8, 1, 128, 64),
    ])
    def test_causal_matches_ref(self, B, H, KV, S, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = (jax.random.normal(ks[0], (B, H, S, d)) * 0.5).astype(dtype)
        k = (jax.random.normal(ks[1], (B, KV, S, d)) * 0.5).astype(dtype)
        v = (jax.random.normal(ks[2], (B, KV, S, d)) * 0.5).astype(dtype)
        got = ops.flash_attention(q, k, v, causal=True, q_block=64,
                                  k_block=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == "bfloat16" else 2e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 32))
        k = jax.random.normal(ks[1], (1, 2, 128, 32))
        v = jax.random.normal(ks[2], (1, 2, 128, 32))
        got = ops.flash_attention(q, k, v, causal=False, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_block_shape_independence(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 32))
        k = jax.random.normal(ks[1], (1, 1, 256, 32))
        v = jax.random.normal(ks[2], (1, 1, 256, 32))
        a = ops.flash_attention(q, k, v, q_block=64, k_block=128,
                                interpret=True)
        b = ops.flash_attention(q, k, v, q_block=256, k_block=32,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


class TestRGLRUScan:
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("B,S,R,chunk", [
        (1, 128, 128, 32), (2, 256, 256, 256), (3, 64, 128, 16),
    ])
    def test_matches_ref(self, B, S, R, chunk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, R))).astype(dtype)
        b = (jax.random.normal(ks[1], (B, S, R)) * 0.1).astype(dtype)
        got = ops.rglru_scan(a, b, seq_chunk=chunk, interpret=True)
        want = ref.rglru_scan_ref(a, b)
        tol = 3e-2 if dtype == "bfloat16" else 1e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_decay_semantics(self):
        # a=0 => h = b; a=1,b=0 => h stays 0
        B, S, R = 1, 64, 128
        z = jnp.zeros((B, S, R))
        o = jnp.ones((B, S, R))
        np.testing.assert_allclose(
            np.asarray(ops.rglru_scan(z, o, interpret=True)), 1.0)
        np.testing.assert_allclose(
            np.asarray(ops.rglru_scan(o, z, interpret=True)), 0.0)
