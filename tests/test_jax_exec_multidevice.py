"""End-to-end: the JAX executor matches the interpreter oracle.

Multi-device CPU requires XLA_FLAGS set before jax initializes, and the
main test process must keep seeing 1 device (per the task spec), so these
tests run a worker script in a subprocess with 8/12/24 host devices.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

WORKER = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + sys.argv[1])
    import numpy as np
    import jax
    from repro.core import Mesh, parse_type, plan_redistribution, plan_xla
    from repro.core.jax_exec import (jax_mesh_of, make_executor,
                                     partition_spec, redistribute_array)
    from repro.core.offsets import base_offset_map, tile_of
    from jax.sharding import NamedSharding

    cases = json.loads(sys.argv[2])
    for case in cases:
        t1s, t2s, meshspec, baseline = case
        mesh = Mesh.make(meshspec)
        t1, t2 = parse_type(t1s), parse_type(t2s)
        jmesh = jax_mesh_of(mesh)
        g = np.arange(np.prod(t1.globaltype()), dtype=np.float32)
        g = g.reshape(t1.globaltype())
        if baseline:
            plan = plan_xla(t1, t2, mesh)
        else:
            plan = plan_redistribution(t1, t2, mesh).plan
        fn, in_spec, out_spec = make_executor(plan, t1, t2, mesh, jmesh)
        x = jax.device_put(g, NamedSharding(jmesh, in_spec))
        y = jax.jit(fn, out_shardings=NamedSharding(jmesh, out_spec))(x)
        # global value must be preserved
        np.testing.assert_array_equal(np.asarray(y), g)
        # per-device tiles must match T[[tau2]]
        beta2 = base_offset_map(t2, mesh)
        for sh in y.addressable_shards:
            expect = tile_of(g, beta2[sh.device.id], t2.localtype())
            np.testing.assert_array_equal(np.asarray(sh.data), expect)
    print("OK", len(cases))
""")


def run_worker(n_devices, cases):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", WORKER, str(n_devices), json.dumps(cases)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert f"OK {len(cases)}" in out.stdout


@pytest.mark.slow
def test_executor_matches_oracle_8dev():
    cases = [
        ["[8, 8{d}64]", "[1{d}8, 64]", {"d": 8}, False],
        ["[2{a}4, 8{b}32]", "[4, 4{a,b}32]", {"a": 2, "b": 4}, False],
        ["[4{a}8, 12{b}48]", "[8, 6{b,a}48]", {"a": 2, "b": 4}, False],
        ["[8{a,b}64, 6]", "[64, 6]", {"a": 2, "b": 4}, False],   # gathers
        ["[16, 6]", "[2{a,b}16, 6]", {"a": 2, "b": 4}, False],   # slices
        ["[4{a}8, 6]", "[4{b2}8, 6]", {"a": 2, "b2": 2, "c": 2}, False],
    ]
    run_worker(8, cases)


@pytest.mark.slow
def test_executor_matches_oracle_24dev_prime_mesh():
    # Example 3.1: the factor-decomposition flagship case, on real devices.
    cases = [
        ["[3{x}12, 2{y}12]", "[2{y}12, 3{x}12]", {"x": 4, "y": 6}, False],
        ["[3{x}12, 2{y}12]", "[2{y}12, 3{x}12]", {"x": 4, "y": 6}, True],
        ["[1{x,y}24, 24]", "[24, 1{x,y}24]", {"x": 4, "y": 6}, False],
    ]
    run_worker(24, cases)


@pytest.mark.slow
def test_xla_baseline_execution_8dev():
    cases = [
        ["[8, 8{d}64]", "[1{d}8, 64]", {"d": 8}, True],
        ["[2{a}4, 8{b}32]", "[4, 4{a,b}32]", {"a": 2, "b": 4}, True],
    ]
    run_worker(8, cases)
