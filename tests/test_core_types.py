"""Unit tests: distributed types, meshes, base offset maps, typing rules."""
import numpy as np
import pytest

from repro.core import (AllGather, AllPermute, AllToAll, DynSlice, Mesh,
                        TypingError, apply, apply_seq, base_offset_map,
                        check_wf, decompose_type, dim, dtype_of, equivalent,
                        parse_type, prime_factors, valid_redistribution)


def mesh(**kw):
    return Mesh.make(kw)


class TestMesh:
    def test_coords_roundtrip(self):
        m = mesh(x=2, y=3, z=2)
        for i, c in enumerate(m.coords()):
            assert m.id_of(c) == i
            assert m.coord_of(i) == c

    def test_prime_decomposition_preserves_device_order(self):
        m = mesh(x=12, y=2)
        dm, sub = m.decompose_primes()
        assert sub["x"] == ("x@0", "x@1", "x@2")
        assert dm.nelems == 24
        # x coordinate c decomposes with x@0 minor (fastest) so that the
        # raveled device order is unchanged.
        for dev in range(24):
            cx, cy = m.coord_of(dev)
            dcoord = dict(zip(dm.names, dm.coord_of(dev)))
            radix = 1
            got = 0
            for s in sub["x"]:
                got += dcoord[s] * radix
                radix *= dm.size(s)
            assert got == cx
            assert dcoord["y"] == cy

    def test_prime_factors(self):
        assert prime_factors(1) == ()
        assert prime_factors(12) == (2, 2, 3)
        assert prime_factors(97) == (97,)


class TestTypes:
    def test_parse_roundtrip(self):
        t = parse_type("[8{x,y}256, 1024]")
        assert t.dims[0].tile == 8 and t.dims[0].axes == ("x", "y")
        assert t.localtype() == (8, 1024)
        assert t.globaltype() == (256, 1024)
        assert str(parse_type(str(t))) == str(t)

    def test_wf(self):
        m = mesh(x=4, y=8)
        check_wf(parse_type("[64{x}256, 1024]"), m)
        with pytest.raises(TypingError):   # sizes do not multiply out
            check_wf(parse_type("[64{x}512, 1024]"), m)
        with pytest.raises(TypingError):   # axis used twice
            check_wf(parse_type("[64{x}256, 256{x}1024]"), m)
        with pytest.raises(TypingError):   # unknown axis
            check_wf(parse_type("[64{q}256]"), m)

    def test_validity_examples_from_paper(self):
        # §2.5: same local shapes but different global arrays -> invalid.
        m = mesh(xdevs=4, ydevs=8)
        t1 = parse_type("[32{xdevs}128, 32{ydevs}256]")
        t2 = parse_type("[32{xdevs,ydevs}1024, 32]")
        assert not valid_redistribution(t1, t2, m)

    def test_decompose_type_offsets_identical(self):
        m = mesh(x=12, y=2)
        t = parse_type("[2{x}24, 8{y}16]")
        dm, _ = m.decompose_primes()
        dt = decompose_type(t, m)
        check_wf(dt, dm)
        assert np.array_equal(base_offset_map(t, m), base_offset_map(dt, dm))


class TestOffsets:
    def test_lemma_4_2_image_is_full_tiling(self):
        # Lemma 4.2: T[[τ]] hits all base offsets below globaltype.
        m = mesh(x=2, y=3, z=2)
        t = parse_type("[4{y,x}24, 6{z}12]")
        beta = base_offset_map(t, m)
        rows = {tuple(r) for r in beta}
        expect = {(a, b) for a in range(0, 24, 4) for b in range(0, 12, 6)}
        assert rows == expect

    def test_minor_major_order(self):
        # [8{x,y}32]: x minor (stride 8), y major (stride 16) over x:2,y:2.
        m = mesh(x=2, y=2)
        t = parse_type("[8{x,y}32]")
        beta = base_offset_map(t, m)
        # device order: (x,y) row-major with y fastest.
        offs = {m.coord_of(d): beta[d, 0] for d in range(4)}
        assert offs[(0, 0)] == 0
        assert offs[(1, 0)] == 8     # x minor: stride 8
        assert offs[(0, 1)] == 16    # y major: stride 16
        assert offs[(1, 1)] == 24

    def test_equivalence_lemma_5_1(self):
        # Same local+global type => permutation equivalent.
        m = mesh(x=4, y=4)
        t1 = parse_type("[64{y,x}1024, 128]")
        t2 = parse_type("[64{x,y}1024, 128]")
        assert equivalent(base_offset_map(t1, m), base_offset_map(t2, m))
        t3 = parse_type("[32{x}128, 16{y}64]")
        t4 = parse_type("[32{y}128, 16{x}64]")
        assert equivalent(base_offset_map(t3, m), base_offset_map(t4, m))


class TestTypingRules:
    def test_allgather_removes_minor_most(self):
        m = mesh(x=4, y=4)
        t = parse_type("[32{x,y}512, 512]")
        out = apply(AllGather(0), t, m)
        assert str(out) == "[128{y}512, 512]"

    def test_allgather_rejects_non_minor(self):
        m = mesh(x=4, y=4)
        t = parse_type("[32{x,y}512, 512]")
        with pytest.raises(TypingError):
            apply(AllGather(0, ("y",)), t, m)

    def test_dynslice(self):
        m = mesh(x=4, y=4)
        t = parse_type("[128{y}512, 512]")
        out = apply(DynSlice(1, ("x",)), t, m)
        assert str(out) == "[128{y}512, 128{x}512]"
        with pytest.raises(TypingError):   # y already used
            apply(DynSlice(1, ("y",)), t, m)
        with pytest.raises(TypingError):   # not divisible
            apply(DynSlice(0, ("x",)), parse_type("[2{y}8, 512]"),
                  mesh(x=3, y=4))

    def test_alltoall(self):
        m = mesh(devs=32)
        t = parse_type("[32, 64{devs}2048]")
        out = apply(AllToAll(1, 0), t, m)
        assert str(out) == "[1{devs}32, 2048]"

    def test_listing3_chain(self):
        # The redistribute from Listing 3 as a single alltoall.
        m = mesh(devs=32)
        t1 = parse_type("[32, 64{devs}2048]")
        t2 = parse_type("[1{devs}32, 2048]")
        types = apply_seq([AllToAll(1, 0)], t1, m)
        assert types[-1] == t2

    def test_permute(self):
        m = mesh(xdev=4, ydev=4)
        t1 = parse_type("[32{xdev}128]")
        t2 = parse_type("[32{ydev}128]")
        out = apply(AllPermute(t2), t1, m)
        assert out == t2
        with pytest.raises(TypingError):
            apply(AllPermute(parse_type("[16{xdev,ydev}256]")), t1, m)

    def test_multi_axis_gather(self):
        m = mesh(x=4, y=4)
        t = parse_type("[32{x,y}512, 512]")
        out = apply(AllGather(0, ("x", "y")), t, m)
        assert str(out) == "[512, 512]"
