"""Launch-layer tests: sharding assembly, HLO parsing, roofline math,
and a true (subprocess) production-mesh dry-run of one small cell."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.configs.registry import SHAPES, get_config
from repro.launch.hlo_analysis import collective_bytes, count_ops, shape_bytes
from repro.launch.roofline import corrected_metrics, model_flops

SRC = str(Path(__file__).resolve().parent.parent / "src")


class TestHLOAnalysis:
    HLO = textwrap.dedent("""
      %x = bf16[128,256]{1,0} all-gather(%a), replica_groups={{0,1}}
      %y = (f32[64]{0}, f32[64]{0}) all-to-all(%b, %c), dimensions={0}
      %z = f32[32,32]{1,0} all-reduce(%d), to_apply=%add
      %w = f32[16]{0} collective-permute-start(%e), source_target_pairs={{0,1}}
      %v = bf16[8,8]{1,0} dot(%f, %g)
    """)

    def test_shape_bytes(self):
        assert shape_bytes("bf16", "128,256") == 128 * 256 * 2
        assert shape_bytes("f32", "") == 4

    def test_collective_bytes(self):
        res = collective_bytes(self.HLO)
        assert res["bytes"]["all-gather"] == 128 * 256 * 2
        assert res["bytes"]["all-to-all"] == 2 * 64 * 4
        assert res["bytes"]["all-reduce"] == 32 * 32 * 4
        assert res["bytes"]["collective-permute"] == 16 * 4
        assert res["count"]["all-to-all"] == 1

    def test_count_ops(self):
        ops = count_ops(self.HLO)
        assert ops["dot"] == 1


class TestRooflineMath:
    def test_corrected_metrics_extrapolation(self):
        cell = {"pattern_len": 1, "pattern_repeats": 10, "remainder_len": 0,
                "flops": 100.0, "bytes_accessed": 10.0,
                "collective_bytes": {"total_bytes": 5}}
        # unrolled probes: outer=40, body=30
        p1 = {"flops": 70.0, "bytes_accessed": 7.0,
              "collective_bytes": {"total_bytes": 3}}
        p2 = {"flops": 100.0, "bytes_accessed": 9.0,
              "collective_bytes": {"total_bytes": 4}}
        m = corrected_metrics(cell, p1, p2)
        assert m["flops"]["corrected"] == pytest.approx(40 + 10 * 30)
        assert m["bytes_accessed"]["corrected"] == pytest.approx(5 + 10 * 2)

    def test_model_flops_train_vs_decode(self):
        cfg = get_config("olmo_1b")
        tr = model_flops(cfg, SHAPES["train_4k"], 256)
        de = model_flops(cfg, SHAPES["decode_32k"], 256)
        assert tr > de * 1000
        # train: 6*N*tokens/dev
        expect = 6 * cfg.active_param_count() * 256 * 4096 / 256
        assert tr == pytest.approx(expect)


class TestProductionDryRun:
    @pytest.mark.slow
    def test_one_cell_on_512_fake_devices(self, tmp_path):
        """The real thing, end to end, for the smallest arch."""
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "qwen2_0_5b", "--shape", "decode_32k", "--multi-pod",
             "--out", str(tmp_path)],
            capture_output=True, text=True, env=env, timeout=900,
            cwd=str(Path(SRC).parent))
        assert out.returncode == 0, out.stderr[-2000:]
        res = json.loads(
            (tmp_path / "qwen2_0_5b.decode_32k.multipod.json").read_text())
        assert res["status"] == "ok"
        assert res["n_devices"] == 512
        assert res["flops"] > 0
        assert res["collective_bytes"]["total_bytes"] > 0


class TestShardingPolicies:
    def test_specs_divisible_everywhere(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.launch.steps import abstract_params
        from repro.sharding import policies

        policies.set_axis_sizes({"data": 16, "model": 16})
        for arch in ("qwen2_0_5b", "mixtral_8x22b", "minicpm3_4b",
                     "xlstm_1_3b"):
            cfg = get_config(arch)
            params = abstract_params(cfg)
            specs = policies.param_specs(params, cfg, data_axes=("data",),
                                         policy="fsdp")
            flat_p = jax.tree.leaves(params)
            flat_s = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            for leaf, spec in zip(flat_p, flat_s):
                for i, ent in enumerate(spec):
                    if ent is None:
                        continue
                    axes = (ent,) if isinstance(ent, str) else ent
                    prod = int(np.prod([16 for _ in axes]))
                    assert leaf.shape[i] % prod == 0, (arch, leaf.shape, spec)
