"""Property-based tests (hypothesis): the paper's theorems on random inputs.

For random (mesh, τ1, τ2) redistribution problems:
  * the synthesized plan is CORRECT (interpreter matches direct re-tiling),
  * it satisfies the MEMORY GUARANTEE h ≤ max(localsize τ1, localsize τ2),
  * it contains at most ONE allpermute (Thm 6.7),
  * its weak kinds are in NORMAL FORM (Thm 4.8),
  * its cost never exceeds the XLA fallback's cost (near-optimality side),
  * the XLA-baseline plan is also correct (baseline validity).
"""
import math
import random

import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (Mesh, is_normal_form, plan_redistribution, plan_xla,
                        verify_plan)
from repro.core.dist_types import DistDim, DistType


@st.composite
def redistribution_problem(draw):
    """Random mesh (2-3 axes), rank 1-3 arrays, random partitionings."""
    n_axes = draw(st.integers(2, 3))
    axis_sizes = [draw(st.sampled_from([2, 2, 2, 3, 4]))
                  for _ in range(n_axes)]
    names = [f"ax{i}" for i in range(n_axes)]
    mesh = Mesh.make(dict(zip(names, axis_sizes)))

    rank = draw(st.integers(1, 3))
    base = [draw(st.sampled_from([1, 2, 3, 4])) for _ in range(rank)]

    def random_type():
        # each mesh axis partitions at most one dim (or is unused)
        placement = {}
        for a in names:
            where = draw(st.integers(-1, rank - 1))
            if where >= 0:
                placement.setdefault(where, []).append(a)
        dims = []
        for i in range(rank):
            axes = tuple(placement.get(i, []))
            prod = math.prod(mesh.size(a) for a in axes)
            glob = base[i] * mesh.nelems  # divisible by any axis subset
            dims.append(DistDim(glob // prod, axes, glob))
        return DistType(tuple(dims))

    return mesh, random_type(), random_type()


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(redistribution_problem())
def test_synthesized_plans_obey_the_paper(problem):
    mesh, t1, t2 = problem
    r = plan_redistribution(t1, t2, mesh)
    res = verify_plan(r.plan, t1, t2, mesh)                 # correctness
    bound = max(math.prod(t1.localtype()), math.prod(t2.localtype()))
    assert res.peak_elems <= bound                          # memory (Thm 4.8)
    assert r.plan.n_permutes() <= 1                         # Thm 6.7
    kinds = [k for k in r.plan.kinds()]
    if kinds and kinds[-1] == "allpermute":
        kinds = kinds[:-1]                                  # Thm 6.7 tail
    assert is_normal_form(kinds)                            # Def. 4.5 (+1 perm)

    xla = plan_xla(t1, t2, mesh)
    verify_plan(xla, t1, t2, mesh)                          # baseline validity
    assert r.plan.cost() <= xla.cost() + math.prod(t2.localtype())


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(redistribution_problem())
def test_time_objective_also_correct(problem):
    mesh, t1, t2 = problem
    r = plan_redistribution(t1, t2, mesh, objective="time")
    verify_plan(r.plan, t1, t2, mesh)
    bound = max(math.prod(t1.localtype()), math.prod(t2.localtype()))
    assert verify_plan(r.plan, t1, t2, mesh).peak_elems <= bound
