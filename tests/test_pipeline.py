"""Pipeline parallelism: staged execution must equal the plain stack."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

WORKER = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.sharding.pipeline import pipeline_forward, split_stages

    L, D, n_stages, n_micro, mb = 8, 16, 4, 6, 2
    ks = jax.random.split(jax.random.PRNGKey(0), L)
    params = {"w": jnp.stack([jax.random.normal(k, (D, D)) * 0.3
                              for k in ks]),
              "b": jnp.zeros((L, D))}

    def apply_layer(lp, x):
        return jnp.tanh(x @ lp["w"] + lp["b"])

    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, D))

    # reference: plain sequential stack
    def ref_fwd(x1):
        def body(h, lp):
            return apply_layer(lp, h), None
        h, _ = jax.lax.scan(body, x1, params)
        return h
    want = jax.vmap(ref_fwd)(x)

    mesh = Mesh(np.array(jax.devices()).reshape(4), ("stage",))
    staged = split_stages(params, n_stages)
    got = pipeline_forward(staged, x, apply_layer, mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential_4stages():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_OK" in out.stdout


def test_split_stages_shapes():
    import jax.numpy as jnp
    from repro.sharding.pipeline import split_stages
    p = {"w": jnp.zeros((8, 3, 5))}
    s = split_stages(p, 4)
    assert s["w"].shape == (4, 2, 3, 5)
