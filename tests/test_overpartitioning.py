"""Over-partitioning (paper §7.2, Fig. 12): transiently slicing along free
mesh axes lowers alltoall cost because dynslice is free."""
import math

from repro.core import Mesh, parse_type, plan_redistribution, verify_plan
from repro.core.weak import mesh_prime_pool


class TestOverPartitioning:
    def test_search_uses_free_axes_when_profitable(self):
        # Fig. 12 flavor: move partitioning between dims while a free axis
        # (z) is available.  With z the plan may slice first (free),
        # alltoall smaller tiles, gather back.
        mesh_with = Mesh.make({"x": 4, "y": 2, "z": 4})
        mesh_without = Mesh.make({"x": 4, "y": 2})
        t1 = "[8{x}32, 16{y}32]"
        t2 = "[16{y}32, 8{x}32]"
        r_with = plan_redistribution(t1, t2, mesh_with)
        r_without = plan_redistribution(t1, t2, mesh_without)
        # both correct
        verify_plan(r_with.plan, r_with.t1, r_with.t2, r_with.mesh)
        verify_plan(r_without.plan, r_without.t1, r_without.t2,
                    r_without.mesh)
        # the free axis can only help (cost model: dynslice is free)
        assert r_with.search.cost <= r_without.search.cost

    def test_overpartitioned_plan_dips_below_endpoints(self):
        # Direct evidence: an intermediate localsize strictly below BOTH
        # endpoint localsizes means the searcher over-partitioned.
        mesh = Mesh.make({"x": 2, "y": 2, "z": 4})
        t1 = parse_type("[8{x}16, 16{y}32]")
        t2 = parse_type("[8{y}16, 16{x}32]")
        r = plan_redistribution(t1, t2, mesh)
        verify_plan(r.plan, t1, t2, mesh)
        lts = [math.prod(c) for c in r.plan.localtypes()]
        lo = min(lts)
        if lo < min(lts[0], lts[-1]):
            # over-partitioning engaged; memory bound still holds
            assert max(lts) <= max(lts[0], lts[-1])
        # regardless: cost is never worse than the 2-alltoall direct route
        assert r.search.cost <= 2 * t1.localsize()
