"""Per-architecture smoke tests on REDUCED configs (task spec f):
one forward/train step on CPU asserting shapes + finiteness, a decode
step, and decode-vs-forward numerical equivalence (cache correctness).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, forward, init_cache, init_params, loss_fn


def make_batch(cfg, key, batch=2, seq=16):
    tk, fk = jax.random.split(key)
    shape = (batch, seq, cfg.n_codebooks) if cfg.n_codebooks > 1 else (batch, seq)
    tokens = jax.random.randint(tk, shape, 0, cfg.vocab)
    b = {"tokens": tokens, "labels": tokens}
    if cfg.frontend:
        b["frontend_embeds"] = jax.random.normal(
            fk, (batch, cfg.frontend_len, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key, batch=2, seq=16)
    logits, aux = forward(params, batch, cfg, remat=False)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    loss, metrics = loss_fn(params, batch, cfg, remat=False)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key, batch=2, seq=8)

    def loss(p):
        return loss_fn(p, batch, cfg, remat=True)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    B, L = 2, 16
    cache = init_cache(cfg, B, L)
    shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, 1)
    tok = jnp.zeros(shape, jnp.int32)
    logits, cache2 = decode_step(params, cache, tok, 0, cfg)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    # structure preserved
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


# Archs whose frontend stub makes teacher-forced decode ambiguous are
# exercised above; the equivalence check runs on the pure-decoder archs.
EQUIV_ARCHS = ["olmo_1b", "qwen2_0_5b", "minicpm3_4b", "stablelm_12b",
               "recurrentgemma_2b", "xlstm_1_3b", "mixtral_8x22b",
               "musicgen_medium"]


@pytest.mark.parametrize("arch", EQUIV_ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the training forward logits —
    the strongest cache/state correctness check we can run on CPU."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    B, S = 2, 8
    batch = make_batch(cfg, key, batch=B, seq=S)
    ref_logits, _ = forward(params, batch, cfg, remat=False)

    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        tok = batch["tokens"][:, t: t + 1]
        logits, cache = decode_step(params, cache, tok, t, cfg)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_spec():
    """Full configs must land near the published parameter counts."""
    expect = {
        "olmo_1b": (0.9e9, 1.6e9),
        "minicpm3_4b": (3.0e9, 5.0e9),
        "stablelm_12b": (10e9, 14e9),
        "qwen2_0_5b": (0.3e9, 0.7e9),
        "internvl2_2b": (1.5e9, 2.6e9),
        "recurrentgemma_2b": (2.0e9, 3.2e9),
        "xlstm_1_3b": (0.9e9, 1.9e9),
        "musicgen_medium": (1.0e9, 2.2e9),
        "arctic_480b": (420e9, 520e9),
        "mixtral_8x22b": (120e9, 150e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_smaller():
    cfg = get_config("arctic_480b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
