"""Search + normal form + lowering + interpreter: paper examples & properties."""
import math

import numpy as np
import pytest

from repro.core import (Mesh, TypingError, base_offset_map, is_normal_form,
                        lower, mesh_prime_pool, normalize, parse_type,
                        plan_cost, plan_height, plan_redistribution, plan_xla,
                        synthesize, verify_plan)
from repro.core.dist_types import decompose_type
from repro.core.normal_form import assert_cost_nonincreasing, explode_primes
from repro.core.weak import WeakOp


def _plan(t1s, t2s, meshspec, **kw):
    return plan_redistribution(t1s, t2s, Mesh.make(meshspec), **kw)


class TestSearch:
    def test_single_alltoall_listing3(self):
        r = _plan("[32, 64{devs}2048]", "[1{devs}32, 2048]", {"devs": 32})
        kinds = r.plan.kinds()
        assert kinds.count("alltoall") == 1
        assert kinds.count("allgather") == 0
        # cost = localsize (Fig. 11)
        assert r.search.cost == 32 * 64

    def test_example_3_1_factor_decomposition(self):
        # [3{x}12, 2{y}12] -> [2{y}12, 3{x}12] over x:4, y:6 — solvable
        # without full replication only via prime decomposition.
        r = _plan("[3{x}12, 2{y}12]", "[2{y}12, 3{x}12]", {"x": 4, "y": 6})
        assert r.search.height <= max(3 * 2, 2 * 3)
        assert r.plan.height() <= 6
        verify_plan(r.plan, r.t1, r.t2, r.mesh)

    def test_example_4_9_merged_alltoall(self):
        # [1{a}8, 8] -> [8, 1{a}8] should be a single (merged) alltoall.
        r = _plan("[1{a}8, 8{}8]", "[8{}8, 1{a}8]", {"a": 8})
        kinds = r.plan.kinds()
        assert kinds == ["alltoall"] or kinds == ["alltoall", "allpermute"]
        assert r.search.cost == 8

    def test_swap_within_dimension_is_permute_only(self):
        # (Fig. 3 lists mesh 4x4 but 64*4*4 != 2048; Fig. 1's 4x8 mesh is
        # the consistent one.)
        r = _plan("[64{ydev,xdev}2048, 128]", "[64{xdev,ydev}2048, 128]",
                  {"xdev": 4, "ydev": 8})
        assert r.search.cost == 0          # weak: free
        kinds = r.plan.kinds()
        assert set(kinds) <= {"allpermute"}
        verify_plan(r.plan, r.t1, r.t2, r.mesh)

    def test_swap_replicated_axis(self):
        r = _plan("[32{xdev}128]", "[32{ydev}128]", {"xdev": 4, "ydev": 4})
        assert set(r.plan.kinds()) <= {"allpermute"}
        verify_plan(r.plan, r.t1, r.t2, r.mesh)

    def test_memory_bound_always_holds(self):
        r = _plan("[3{x}12, 2{y}12]", "[2{y}12, 3{x}12]", {"x": 4, "y": 6})
        res = verify_plan(r.plan, r.t1, r.t2, r.mesh)
        assert res.peak_elems <= max(6, 6)

    def test_identity(self):
        r = _plan("[4{x}16, 8]", "[4{x}16, 8]", {"x": 4})
        assert r.plan.ops == []

    def test_invalid_redistribution_rejected(self):
        with pytest.raises(TypingError):
            _plan("[512, 32{devs}1024]", "[1024, 32{devs}1024]", {"devs": 32})

    def test_figure5_row1(self):
        # [32{x,y}512, 128] -> [128{y}512, 32{x}128] over x:4,y:4
        r = _plan("[32{x,y}512, 128]", "[128{y}512, 32{x}128]",
                  {"x": 4, "y": 4})
        verify_plan(r.plan, r.t1, r.t2, r.mesh)
        assert r.plan.height() <= max(32 * 128, 128 * 32)

    def test_time_objective_prefers_fewer_ops_on_small_arrays(self):
        # Beyond-paper: latency-aware search avoids long op chains for
        # tiny transfers (the paper's Fig. 13 pathology).
        m = {"a": 2, "b": 2, "c": 2}
        t1, t2 = "[4{a}8, 2{b}4, 8]", "[4{b}8, 2{a}4, 8]"
        rp = _plan(t1, t2, m, objective="paper")
        rt = _plan(t1, t2, m, objective="time")
        assert len(rt.plan.ops) <= len(rp.plan.ops) + 1
        verify_plan(rt.plan, rt.t1, rt.t2, rt.mesh)


class TestNormalForm:
    def test_regex(self):
        assert is_normal_form(["dynslice", "alltoall", "allgather"])
        assert is_normal_form(["alltoall"])
        assert is_normal_form([])
        assert not is_normal_form(["allgather", "dynslice"])
        assert not is_normal_form(["alltoall", "dynslice"])

    def test_normalize_gather_slice_peak(self):
        # gather;slice on different dims with equal prime -> alltoall.
        mesh = Mesh.make({"x": 2, "y": 2})
        pool = mesh_prime_pool(mesh)
        c0 = (2, 8)
        g = (4, 8)
        ops = [WeakOp("allgather", 0, 2), WeakOp("dynslice", 1, 2)]
        nf = normalize(ops, c0, g, pool)
        assert [o.kind for o in nf] == ["alltoall"]
        assert_cost_nonincreasing(ops, nf, c0, g, pool)
        # Height drops from 4*8 to 2*8.
        assert plan_height(nf, c0, g, pool) < plan_height(ops, c0, g, pool)

    def test_normalize_full_fallback(self):
        # allgather-everything then dynslice-everything (paper eq. (2)).
        mesh = Mesh.make({"x": 4, "y": 6})
        pool = mesh_prime_pool(mesh)
        g = (12, 12)
        c0 = (3, 2)
        ops = [WeakOp("allgather", 0, 4), WeakOp("allgather", 1, 6),
               WeakOp("dynslice", 1, 6), WeakOp("dynslice", 0, 4)]
        # endpoint localtype (3,2) -> same; normalization must reach NF.
        nf = normalize(ops, c0, g, pool)
        assert is_normal_form([o.kind for o in nf])
        assert_cost_nonincreasing(ops, nf, c0, g, pool)
        assert plan_height(nf, c0, g, pool) <= max(6, 6)

    def test_explode_primes(self):
        ops = [WeakOp("allgather", 0, 12)]
        ex = explode_primes(ops)
        assert [o.m for o in ex] == [2, 2, 3]


class TestLoweringAndInterp:
    def test_verify_many_cases(self):
        cases = [
            ("[32, 64{d}2048]", "[1{d}32, 2048]", {"d": 32}),
            ("[8{x}16, 6{y}12]", "[16, 3{x,y}12]", {"x": 2, "y": 2}),
            ("[4{x,y}16, 9]", "[16, 9]", {"x": 2, "y": 2}),
            ("[12, 10]", "[6{a}12, 5{b}10]", {"a": 2, "b": 2}),
            ("[6{a}12, 5{b}10]", "[12, 10]", {"a": 2, "b": 2}),
            ("[3{x}12, 2{y}12]", "[2{y}12, 3{x}12]", {"x": 4, "y": 6}),
            ("[2{x}4, 3{y}9, 5{z}10]", "[1{x,z}4, 3{y}9, 10]",
             {"x": 2, "y": 3, "z": 2}),
        ]
        for t1, t2, m in cases:
            r = _plan(t1, t2, m)
            res = verify_plan(r.plan, r.t1, r.t2, r.mesh)
            bound = max(math.prod(r.t1.localtype()),
                        math.prod(r.t2.localtype()))
            assert res.peak_elems <= bound, (t1, t2, m)
            assert r.plan.n_permutes() <= 1

    def test_permute_elision_on_aligned_targets(self):
        # Slicing toward a target the lowering can match -> no permute.
        r = _plan("[12, 10]", "[6{a}12, 10]", {"a": 2, "b": 2})
        assert r.plan.n_permutes() == 0

    def test_xla_baseline_correct_but_memory_hungry(self):
        m = Mesh.make({"x": 4, "y": 6})
        t1 = parse_type("[3{x}12, 2{y}12]")
        t2 = parse_type("[2{y}12, 3{x}12]")
        plan = plan_xla(t1, t2, m)
        res = verify_plan(plan, t1, t2, m)
        # XLA falls back to full replication here: peak = whole array.
        assert res.peak_elems == 144
        # Ours is bounded by the tile sizes.
        r = _plan("[3{x}12, 2{y}12]", "[2{y}12, 3{x}12]", {"x": 4, "y": 6})
        ours = verify_plan(r.plan, r.t1, r.t2, r.mesh)
        assert ours.peak_elems <= 6

    def test_xla_baseline_single_alltoall(self):
        m = Mesh.make({"d": 32})
        t1 = parse_type("[32, 64{d}2048]")
        t2 = parse_type("[1{d}32, 2048]")
        plan = plan_xla(t1, t2, m)
        assert plan.kinds().count("alltoall") == 1
        verify_plan(plan, t1, t2, m)

    def test_xla_baseline_permute(self):
        m = Mesh.make({"x": 4, "y": 4})
        t1 = parse_type("[32{x}128]")
        t2 = parse_type("[32{y}128]")
        plan = plan_xla(t1, t2, m)
        assert set(plan.kinds()) <= {"allpermute"}
        verify_plan(plan, t1, t2, m)
