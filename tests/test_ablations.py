"""Ablations for the beyond-paper optimizations (B1/B2)."""
import math
import random

from repro.core import Mesh, lower, parse_type
from repro.core.api import plan_redistribution
from repro.core.dist_types import decompose_type
from repro.core.interp import verify_plan
from repro.core.search import synthesize


class TestAssignmentMatchingB2:
    def test_matching_never_adds_permutes(self):
        """B2 (greedy pullback + biased dynslice) vs naive lowering:
        matched lowering produces <= permutes, with both plans correct."""
        cases = [
            ("[12, 10]", "[6{a}12, 5{b}10]", {"a": 2, "b": 2}),
            ("[8{a,b}64, 6]", "[64, 6]", {"a": 2, "b": 4}),
            ("[3{x}12, 2{y}12]", "[2{y}12, 3{x}12]", {"x": 4, "y": 6}),
            ("[16, 6]", "[2{a,b}16, 6]", {"a": 2, "b": 4}),
        ]
        for t1s, t2s, meshspec in cases:
            mesh = Mesh.make(meshspec)
            t1, t2 = parse_type(t1s), parse_type(t2s)
            dmesh, _ = mesh.decompose_primes()
            res = synthesize(decompose_type(t1, mesh),
                             decompose_type(t2, mesh), dmesh)
            matched = lower(res.ops, t1, t2, mesh, match_assignment=True)
            naive = lower(res.ops, t1, t2, mesh, match_assignment=False)
            verify_plan(matched, t1, t2, mesh)
            verify_plan(naive, t1, t2, mesh)
            assert matched.n_permutes() <= naive.n_permutes()

    def test_matching_elides_permute_on_slices(self):
        mesh = Mesh.make({"a": 2, "b": 2})
        t1, t2 = parse_type("[16, 6]"), parse_type("[4{a,b}16, 6]")
        dmesh, _ = mesh.decompose_primes()
        res = synthesize(decompose_type(t1, mesh), decompose_type(t2, mesh),
                         dmesh)
        matched = lower(res.ops, t1, t2, mesh, match_assignment=True)
        assert matched.n_permutes() == 0


class TestLatencyAwareB1:
    def test_latency_objective_never_plans_more_ops_on_tiny_arrays(self):
        rng = random.Random(7)
        mesh = Mesh.make({"a": 2, "b": 2, "c": 2})
        for _ in range(10):
            # tiny arrays: latency dominates; fewer collectives preferred
            t1s = "[8{a}16, 4{b}8, 6]"
            t2s = "[4{a,b}16, 8, 6]" if rng.random() < 0.5 \
                else "[8{b}16, 4{a}8, 6]"
            rp = plan_redistribution(t1s, t2s, mesh, objective="paper")
            rt = plan_redistribution(t1s, t2s, mesh, objective="time")
            assert len(rt.plan.ops) <= len(rp.plan.ops) + 1
            verify_plan(rt.plan, rt.t1, rt.t2, rt.mesh)
