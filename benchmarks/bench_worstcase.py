"""Fig. 13 reproduction: the problems where the paper's objective loses to
XLA's fewer-collectives plans, and the latency-aware objective's fix."""
from __future__ import annotations

import math

from repro.core import plan_redistribution, plan_xla
from .bench_vs_xla import HW, plan_time
from .problems import MESH, sample_many


def run(n=150, seed=42, k=4):
    worst = []
    for t1, t2 in sample_many(n, seed):
        ours = plan_redistribution(t1, t2, MESH).plan
        base = plan_xla(t1, t2, MESH)
        to, tx = plan_time(ours), plan_time(base)
        if to > tx:
            lat = plan_redistribution(t1, t2, MESH, objective="time").plan
            worst.append({
                "src": str(t1), "dst": str(t2),
                "mb": math.prod(t1.globaltype()) * 4 / 1e6,
                "slowdown": to / tx,
                "fixed_slowdown": plan_time(lat) / tx,
            })
    worst.sort(key=lambda r: -r["slowdown"])
    return worst[:k]


def rows():
    worst = run()
    if not worst:
        return [("worstcase_slowdowns", 0.0,
                 "no problems where XLA beats the paper objective "
                 "under the time model")]
    out = []
    for i, w in enumerate(worst):
        out.append((f"worstcase_P{i + 1}", w["slowdown"],
                    f"{w['mb']:.0f}MB fixed_by_latency_aware="
                    f"{w['fixed_slowdown']:.2f} src={w['src']}"))
    return out
