"""Elastic re-scaling benchmark (production feature): re-sharding model
state for a TP-degree change 16 -> 8 on the 256-chip production mesh.

This is the factor-decomposition regime (paper Ex. 3.1): the new layout
moves *part* of the model axis onto another dimension, which XLA's
dim-wise heuristics cannot express — it falls back to full replication —
while the prime-decomposed search finds bounded-memory alltoall chains.
One row per parameter class of a stablelm-12b-like block.
"""
from __future__ import annotations

from repro.core import Mesh as CMesh
from repro.core.api import plan_redistribution
from repro.core.dist_types import DistDim, DistType
from repro.core.xla_baseline import plan_xla

MESH = CMesh.make({"data": 16, "model": 16})
DM, _ = MESH.decompose_primes()   # data@0..3, model@0..3 (all size 2)

M = ("model@0", "model@1", "model@2", "model@3")
D_ = ("data@0", "data@1", "data@2", "data@3")


def t(dims):
    return DistType(tuple(DistDim(*d) for d in dims))


# (name, old layout, new layout)
SCENARIOS = [
    # TP-degree change 16 -> 8 (+DP on weights): single-alltoall regime,
    # where XLA's heuristics are competitive — parity expected.
    ("attn/wq (5120x5120)",
     t([(5120, (), 5120), (320, M, 5120)]),
     t([(2560, (M[3],), 5120), (640, M[:3], 5120)])),
    ("mlp/wi (5120x13824)",
     t([(5120, (), 5120), (864, M, 13824)]),
     t([(2560, (M[3],), 5120), (1728, M[:3], 13824)])),
    # ZeRO-1 moment re-mapping: tile-preserving -> pure permutation.
    ("opt.mu mlp/wi (zero1 remap)",
     t([(320, D_, 5120), (864, M, 13824)]),
     t([(320, (M[3],) + D_[:3], 5120), (864, M[:3] + (D_[3],), 13824)])),
    # EP -> dense-TP conversion of MoE experts (serving layout): three
    # dimensions change partitioning at once — XLA's dim-wise path
    # conflicts and falls back to full replication; the search finds a
    # bounded alltoall chain (paper Ex. 3.1 regime, at scale).
    ("moe/experts EP->TP (64x7168x4864)",
     t([(32, (D_[0],), 64), (3584, (D_[1],), 7168),
        (1216, (M[0], M[1]), 4864)]),
     t([(64, (), 64), (7168, (), 7168),
        (304, (D_[0], D_[1], M[0], M[1]), 4864)])),
]


def run():
    rows = []
    for name, t1, t2 in SCENARIOS:
        ours = plan_redistribution(t1, t2, DM).plan
        base = plan_xla(t1, t2, DM)
        bound = max(t1.localsize(), t2.localsize())
        rows.append({
            "name": name,
            "ours_cost": ours.cost(), "xla_cost": base.cost(),
            "ours_peak": ours.height(), "xla_peak": base.height(),
            "bound": bound,
        })
    return rows


def rows():
    out = []
    for r in run():
        saving = (r["xla_cost"] + 1) / (r["ours_cost"] + 1)
        peak = (r["xla_peak"] + 1) / (r["ours_peak"] + 1)
        out.append((f"elastic_tp16to8[{r['name'].split()[0]}]", saving,
                    f"transfer_saving={saving:.2f}x peak_saving={peak:.2f}x "
                    f"ours_peak<=bound={r['ours_peak'] <= r['bound']} "
                    f"xla_peak/bound={r['xla_peak'] / r['bound']:.1f}"))
    return out
