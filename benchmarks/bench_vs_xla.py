"""RQ2/RQ3 (paper §8, Fig. 13/14): synthesized plans vs the XLA SPMD
baseline under the hardware time model, plus memory peaks.

Paper: geomean speedup 1.22x, max 5.7x, slowdowns up to 1.6x on small
latency-bound transfers.  We report the same statistics for (a) the
paper-faithful cost objective and (b) the beyond-paper latency-aware
objective (the paper's own future-work suggestion), which should remove
the slowdown tail.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import HardwareModel, plan_redistribution, plan_xla
from repro.core.plan import PAllToAll, PGather, PPermute, PSlice
from .problems import MESH, sample_many

HW = HardwareModel(link_bw_bytes=50e9, latency_s=8e-6, elem_bytes=4)


def plan_time(plan, hw=HW) -> float:
    t = 0.0
    lts = plan.localtypes()
    for op, cin, cout in zip(plan.ops, lts[:-1], lts[1:]):
        kind = {PSlice: "dynslice", PGather: "allgather",
                PAllToAll: "alltoall", PPermute: "allpermute"}[type(op)]
        t += hw.step_time(kind, math.prod(cin), math.prod(cout))
    return t


def run(n=150, seed=42):
    problems = sample_many(n, seed)
    recs = []
    for t1, t2 in problems:
        ours = plan_redistribution(t1, t2, MESH).plan
        ours_lat = plan_redistribution(t1, t2, MESH, objective="time").plan
        base = plan_xla(t1, t2, MESH)
        recs.append({
            "mb": math.prod(t1.globaltype()) * 4 / 1e6,
            "permutes_ours": ours.n_permutes(),
            "t_ours": plan_time(ours),
            "t_ours_lat": plan_time(ours_lat),
            "t_xla": plan_time(base),
            "peak_ours": ours.height(),
            "peak_xla": base.height(),
            "bound": max(math.prod(t1.localtype()),
                         math.prod(t2.localtype())),
        })
    return recs


def _geomean(x):
    return float(np.exp(np.mean(np.log(np.maximum(x, 1e-12)))))


def summarize(recs):
    eps = 1e-9   # both-identity plans compare equal, not as 0x
    sp = np.array([(r["t_xla"] + eps) / (r["t_ours"] + eps) for r in recs])
    sp_lat = np.array([(r["t_xla"] + eps) / (r["t_ours_lat"] + eps)
                       for r in recs])
    mem_ok = np.array([r["peak_ours"] <= r["bound"] for r in recs])
    mem_xla_over = np.array([r["peak_xla"] > r["bound"] for r in recs])
    mem_ratio = np.array([r["peak_xla"] / r["bound"] for r in recs])
    return {
        "geomean_speedup": _geomean(sp),
        "max_speedup": float(sp.max()),
        "slowdown_frac": float((sp < 1.0).mean()),
        "worst_slowdown": float(sp.min()),
        "geomean_speedup_latencyaware": _geomean(sp_lat),
        "slowdown_frac_latencyaware": float((sp_lat < 1.0).mean()),
        "permute_free_frac": float(np.mean(
            [r["permutes_ours"] == 0 for r in recs])),
        "mem_guarantee_frac_ours": float(mem_ok.mean()),
        "mem_violation_frac_xla": float(mem_xla_over.mean()),
        "mean_xla_peak_over_bound": float(mem_ratio.mean()),
        "max_xla_peak_over_bound": float(mem_ratio.max()),
    }


def rows():
    recs = run()
    s = summarize(recs)
    return [
        ("rq2_geomean_speedup_vs_xla", s["geomean_speedup"],
         f"max={s['max_speedup']:.2f} slowdown_frac={s['slowdown_frac']:.3f} "
         f"worst={s['worst_slowdown']:.2f} (paper: 1.22x geomean, 5.7x max)"),
        ("rq3_latency_aware_geomean", s["geomean_speedup_latencyaware"],
         f"slowdown_frac={s['slowdown_frac_latencyaware']:.3f} "
         f"(beyond-paper: latency-aware cost removes the Fig.13 tail)"),
        ("permute_elision_b2", s["permute_free_frac"],
         "fraction of plans with ZERO allpermute (Thm 6.7 allows one; "
         "assignment-matched lowering elides it)"),
        ("memory_guarantee", s["mem_guarantee_frac_ours"],
         f"xla_violations={s['mem_violation_frac_xla']:.3f} "
         f"xla_peak_over_bound_mean={s['mean_xla_peak_over_bound']:.2f} "
         f"max={s['max_xla_peak_over_bound']:.2f}"),
    ]
