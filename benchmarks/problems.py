"""Shared random redistribution-problem sampler (paper §8 methodology):
global arrays 64–800 MB (fp32), 3 mesh axes, up to 6-D arrays; each axis
replicated or partitioning one random dimension.
"""
from __future__ import annotations

import math
import random

from repro.core import Mesh
from repro.core.dist_types import DistDim, DistType

MESH = Mesh.make({"a": 2, "b": 2, "c": 2})   # 8 devices, as evaluated


def sample_problem(rng: random.Random, min_mb=64, max_mb=800):
    rank = rng.randint(1, 6)
    target_elems = rng.uniform(min_mb, max_mb) * 1e6 / 4
    # dim sizes: multiples of 64 (divisible by any axis subset), random split
    logs = sorted(rng.uniform(0, 1) for _ in range(rank - 1))
    parts = [b - a for a, b in zip([0] + logs, logs + [1])]
    sizes = []
    for p in parts:
        s = max(64, int(round(target_elems ** p / 64)) * 64)
        sizes.append(s)
    # adjust first dim to land near target
    prod_rest = math.prod(sizes[1:]) if rank > 1 else 1
    first = max(64, int(round(target_elems / prod_rest / 64)) * 64)
    sizes[0] = first

    def random_type():
        placement = {}
        for ax in MESH.names:
            where = rng.randint(-1, rank - 1)
            if where >= 0:
                placement.setdefault(where, []).append(ax)
        dims = []
        for i, s in enumerate(sizes):
            axes = tuple(placement.get(i, ()))
            prod = math.prod(MESH.size(a) for a in axes)
            dims.append(DistDim(s // prod, axes, s))
        return DistType(tuple(dims))

    return random_type(), random_type()


def sample_many(n: int, seed: int = 42, **kw):
    rng = random.Random(seed)
    out = []
    while len(out) < n:
        t1, t2 = sample_problem(rng, **kw)
        if t1 != t2:
            out.append((t1, t2))
    return out
