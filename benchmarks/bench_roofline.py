"""Roofline digest for the benchmark CSV (full table in EXPERIMENTS.md)."""
from __future__ import annotations

from pathlib import Path


def rows():
    from repro.launch.roofline import analyze
    if not Path("experiments/dryrun").exists():
        return [("roofline_summary", 0.0, "no dry-run data")]
    rws = [r for r in analyze("experiments/dryrun") if r.get("status") == "ok"]
    if not rws:
        return [("roofline_summary", 0.0, "no ok cells")]
    out = []
    from collections import Counter
    doms = Counter(r["bottleneck"] for r in rws)
    fracs = sorted(r["roofline_fraction"] for r in rws)
    out.append(("roofline_cells", float(len(rws)),
                f"bottlenecks={dict(doms)} "
                f"median_roofline_fraction={fracs[len(fracs) // 2]:.2f}"))
    worst = min(rws, key=lambda r: r["roofline_fraction"])
    out.append(("roofline_worst_cell", worst["roofline_fraction"],
                f"{worst['arch']}/{worst['shape']} bottleneck="
                f"{worst['bottleneck']}"))
    return out
