"""Benchmark harness — one section per paper table/figure.
Prints ``name,value,derived`` CSV rows (value unit noted per row).

  rq1_search_time        — §8 RQ1 (synthesis under a second)
  rq2_geomean_speedup    — §8 RQ2 / Fig. 14 (vs XLA SPMD baseline)
  rq3_latency_aware      — §8 RQ3 / Fig. 13 tail (beyond-paper objective)
  memory_guarantee       — §4 Thm 4.8 (peak <= max(in, out); XLA violates)
  worstcase_table        — Fig. 13 reproduction (biggest slowdowns)
  elastic_reshard        — production feature benchmark
  roofline_summary       — §Roofline digest (if dry-run data present)
"""
from __future__ import annotations


def main() -> None:
    from . import bench_search, bench_vs_xla, bench_worstcase, bench_elastic

    rows = []
    rows += bench_search.rows()
    rows += bench_vs_xla.rows()
    rows += bench_worstcase.rows()
    rows += bench_elastic.rows()
    try:
        from . import bench_roofline
        rows += bench_roofline.rows()
    except Exception as e:  # dry-run data may not exist yet
        rows.append(("roofline_summary", 0.0, f"unavailable: {e}"))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
