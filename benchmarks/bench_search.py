"""RQ1 (paper §8): synthesis time for random redistribution problems.
Paper claim: every problem synthesized in under a second (non-optimized
Python).  We report mean / p95 / max wall time and the pass rate."""
from __future__ import annotations

import time

import numpy as np

from repro.core import plan_redistribution
from .problems import MESH, sample_many


def run(n=150, seed=42):
    problems = sample_many(n, seed)
    times = []
    for t1, t2 in problems:
        t0 = time.perf_counter()
        plan_redistribution(t1, t2, MESH)
        times.append(time.perf_counter() - t0)
    times = np.array(times)
    return {
        "name": "rq1_search_time",
        "n": n,
        "mean_s": float(times.mean()),
        "p95_s": float(np.percentile(times, 95)),
        "max_s": float(times.max()),
        "under_1s_frac": float((times < 1.0).mean()),
    }


def rows():
    r = run()
    return [("rq1_search_time_mean", r["mean_s"] * 1e6,
             f"p95={r['p95_s'] * 1e6:.0f}us max={r['max_s'] * 1e6:.0f}us "
             f"under1s={r['under_1s_frac']:.3f} n={r['n']}")]
