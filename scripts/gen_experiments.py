"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun + experiments/perf + a fresh benchmark run."""
import json
import subprocess
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, "src")

from repro.launch.roofline import analyze, to_markdown  # noqa: E402


def dryrun_section():
    cells = []
    for f in sorted(Path("experiments/dryrun").glob("*.json")):
        name = f.name
        if ".L" in name or ".V_" in name:
            continue
        cells.append(json.loads(f.read_text()))
    by_status = Counter(c["status"] for c in cells)
    lines = ["## §Dry-run\n",
             f"All {len(cells)} cells = 10 archs x 4 shapes x 2 meshes "
             f"(16x16 single-pod = 256 chips; 2x16x16 multi-pod = 512 "
             f"chips): **{by_status['ok']} compile OK, "
             f"{by_status.get('skipped', 0)} documented skips "
             f"(long_500k on quadratic-attention archs), "
             f"{by_status.get('error', 0)} failures.**\n",
             "Per-cell records (flops, bytes, per-collective bytes/counts, "
             "memory analysis, compile time) live in `experiments/dryrun/"
             "*.json`.  Summary (multi-pod mesh):\n",
             "| arch | shape | status | compile(s) | HLO flops/dev | "
             "collective bytes/dev | temp/dev |",
             "|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if c["mesh"] != "2x16x16":
            continue
        if c["status"] == "ok":
            lines.append(
                f"| {c['arch']} | {c['shape']} | ok | {c['compile_s']} "
                f"| {c['flops']:.3g} "
                f"| {c['collective_bytes']['total_bytes']:.3g} "
                f"| {c.get('temp_size_in_bytes', 0) / 1e9:.1f}GB |")
        else:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['status']} "
                         f"| — | — | — | — |")
    return "\n".join(lines) + "\n"


def roofline_section():
    rows = analyze("experiments/dryrun")
    md = to_markdown(rows)
    doms = Counter(r["bottleneck"] for r in rows if r.get("status") == "ok")
    notes = {
        "memory": "HLO bytes-accessed is an unfused upper bound on HBM "
                  "traffic; on TPU, fusion + the Pallas kernels move these "
                  "cells toward their compute terms.",
        "collective": "dominated by parameter all-gathers (FSDP) or "
                      "KV-cache re-broadcasts; see §Perf for the fixes.",
    }
    out = ["## §Roofline (single-pod 16x16, per device)\n",
           "Terms: compute = corrected HLO flops / 197 TF/s; memory = "
           "corrected HLO bytes / 819 GB/s; collective = HLO collective "
           "bytes / 50 GB/s.  'roofline' = (MODEL_FLOPS/peak) / limiting "
           "term — the MFU bound of the configuration; 'useful' = "
           "MODEL_FLOPS / HLO flops (remat/redundancy waste).\n",
           "Loop correction: XLA cost analysis counts while-loop bodies "
           "once, so totals are reconstructed from unrolled probe compiles "
           "(see `launch/roofline.py`; probes in experiments/dryrun/*.U.json)."
           "\n", md, "",
           f"Bottleneck census: {dict(doms)}.",
           f"- memory-bound cells: {notes['memory']}",
           f"- collective-bound cells: {notes['collective']}"]
    return "\n".join(out) + "\n"


def perf_section():
    log_path = Path("experiments/perf/log.json")
    if not log_path.exists():
        return "## §Perf\n(pending)\n"
    log = [r for r in json.loads(log_path.read_text()) if "error" not in r]
    lines = ["## §Perf — hillclimb measurements (see narrative below)\n",
             "| cell | variant | compute(s) | memory(s) | collective(s) | "
             "temp/dev |",
             "|---|---|---|---|---|---|"]
    for r in log:
        lines.append(
            f"| {r['arch']}/{r['shape']} | {r['variant']} "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} "
            f"| {r['t_collective_s']:.4f} | {r['temp_gb']:.1f}GB |")
    return "\n".join(lines) + "\n"


def bench_section():
    out = subprocess.run([sys.executable, "-m", "benchmarks.run"],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    return ("## §Benchmarks (paper tables)\n\n```\n" + out.stdout.strip()
            + "\n```\n")


def main():
    gen = "\n".join([dryrun_section(), roofline_section(), perf_section(),
                     bench_section()])
    path = Path("EXPERIMENTS.md")
    text = path.read_text() if path.exists() else ""
    marker = "<!-- GENERATED BELOW -->"
    head = text.split(marker)[0] if marker in text else text
    path.write_text(head + marker + "\n\n" + gen)
    print("EXPERIMENTS.md regenerated")


if __name__ == "__main__":
    main()
