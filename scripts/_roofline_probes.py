import glob
import json
import subprocess
import sys
from pathlib import Path

for f in sorted(glob.glob("experiments/dryrun/*.singlepod.json")):
    if ".L" in Path(f).name:
        continue
    r = json.load(open(f))
    if r.get("status") != "ok":
        continue
    arch, shape = r["arch"], r["shape"]
    p = r["pattern_len"]
    for L in (p, 2 * p):
        tag = f"{arch}.{shape}.singlepod.{r['policy']}.L{L}.U"
        if Path(f"experiments/dryrun/{tag}.json").exists():
            continue
        cmd = ["timeout", "1800", sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--layers", str(L),
               "--unroll",
               "--policy", r["policy"], "--out", "experiments/dryrun"]
        subprocess.run(cmd)
