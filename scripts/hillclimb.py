"""§Perf hillclimb driver: run (cell × variant) dry-runs + unrolled probes,
compute corrected roofline terms, and append structured records to
experiments/perf/log.json.

Usage: PYTHONPATH=src python scripts/hillclimb.py CELL=VARIANT [...]
  e.g. stablelm_12b:train_4k=attnchunk512 arctic_480b:train_4k=etp
A variant of "" is the baseline (already present from the main sweep).
"""
import json
import subprocess
import sys
from pathlib import Path

OUT = Path("experiments/dryrun")
PERF = Path("experiments/perf")
PERF.mkdir(parents=True, exist_ok=True)

sys.path.insert(0, "src")
from repro.configs.registry import get_config  # noqa: E402


def run_one(arch, shape, variant, policy=None, unroll=False, layers=None):
    tag = f"{arch}.{shape}.singlepod"
    if policy:
        tag += f".{policy}"
    if layers is not None:
        tag += f".L{layers}"
    if unroll:
        tag += ".U"
    if variant:
        tag += f".V_{variant}"
    path = OUT / f"{tag}.json"
    if not path.exists():
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", str(OUT)]
        if policy:
            cmd += ["--policy", policy]
        if layers is not None:
            cmd += ["--layers", str(layers)]
        if unroll:
            cmd += ["--unroll"]
        if variant:
            cmd += ["--variant", variant]
        subprocess.run(["timeout", "2400"] + cmd, check=False)
    if not path.exists():
        raise RuntimeError(f"missing {path}")
    return json.loads(path.read_text())


def corrected(arch, shape, variant):
    from repro.launch.roofline import (HW, corrected_metrics,
                                       _slstm_extra_flops, model_flops)
    from repro.configs.registry import SHAPES

    cfg = get_config(arch)
    p = len(cfg.pattern)
    cell = run_one(arch, shape, variant)
    if cell.get("status") != "ok":
        raise RuntimeError(f"{arch}.{shape} V={variant}: {cell}")
    pol = cell["policy"]
    p1 = run_one(arch, shape, variant, policy=pol, unroll=True, layers=p)
    p2 = run_one(arch, shape, variant, policy=pol, unroll=True, layers=2 * p)
    mets = corrected_metrics(cell, p1, p2)
    n_dev = cell["n_devices"]
    sh = SHAPES[shape]
    flops = mets["flops"]["corrected"] + _slstm_extra_flops(cfg, sh, n_dev)
    rec = {
        "arch": arch, "shape": shape, "variant": variant or "baseline",
        "policy": pol,
        "t_compute_s": flops / HW["peak_flops"],
        "t_memory_s": mets["bytes_accessed"]["corrected"] / HW["hbm_bw"],
        "t_collective_s": mets["collective"]["corrected"] / HW["ici_bw"],
        "temp_gb": cell.get("temp_size_in_bytes", 0) / 1e9,
        "model_flops": model_flops(cfg, sh, n_dev),
        "flops": flops,
        "collective_counts": cell["collective_bytes"]["count"],
        "compile_s": cell.get("compile_s"),
    }
    return rec


def main():
    log_path = PERF / "log.json"
    log = json.loads(log_path.read_text()) if log_path.exists() else []
    for spec in sys.argv[1:]:
        cell, _, variant = spec.partition("=")
        arch, _, shape = cell.partition(":")
        key = (arch, shape, variant or "baseline")
        if any((r["arch"], r["shape"], r["variant"]) == key for r in log):
            print(f"[hillclimb] {key}: cached")
            continue
        print(f"[hillclimb] {key}: running...", flush=True)
        try:
            rec = corrected(arch, shape, variant)
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "variant": variant or "baseline", "error": str(e)[:500]}
        log.append(rec)
        log_path.write_text(json.dumps(log, indent=2))
        print(f"[hillclimb] {key}: {json.dumps(rec, default=str)[:300]}",
              flush=True)


if __name__ == "__main__":
    main()
