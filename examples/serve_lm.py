"""Batched serving with continuous batching: submit staggered requests,
watch slots fill/drain.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np


def main():
    import jax
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serve.engine import Request, ServeEngine

    cfg = ModelConfig(name="demo", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=256, vocab=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch_slots=3, max_len=64)

    rng = np.random.RandomState(0)
    for i in range(7):
        eng.submit(Request(rid=i,
                           prompt=rng.randint(0, cfg.vocab, size=3 + i % 4),
                           max_new_tokens=6))
    steps = eng.run_until_drained()
    print(f"drained 7 requests across 3 slots in {steps} engine steps")
    print("sample generations (greedy):")


if __name__ == "__main__":
    main()
