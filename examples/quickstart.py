"""Quickstart: synthesize, verify, and execute a memory-efficient
redistribution (paper Example 3.1 — the factor-decomposition flagship).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=24")

import numpy as np


def main():
    import jax
    from repro.core import (Mesh, parse_type, plan_redistribution, plan_xla,
                            verify_plan)
    from repro.core.jax_exec import jax_mesh_of, make_executor, partition_spec
    from jax.sharding import NamedSharding

    mesh = Mesh.make({"x": 4, "y": 6})
    t1 = parse_type("[3{x}12, 2{y}12]")
    t2 = parse_type("[2{y}12, 3{x}12]")
    print(f"redistribute {t1} ~> {t2} over mesh x:4, y:6 (24 devices)\n")

    r = plan_redistribution(t1, t2, mesh)
    print("synthesized plan :", r.plan.describe())
    print("transfer cost    :", r.plan.cost(), "elements/device (Fig. 11)")
    print("peak memory      :", r.plan.height(), "elements/device",
          f"(bound = {max(t1.localsize(), t2.localsize())})")

    base = plan_xla(t1, t2, mesh)
    print("\nXLA-style plan   :", base.describe())
    print("transfer cost    :", base.cost())
    print("peak memory      :", base.height(),
          "<- full replication (the paper's eq. (2) fallback)")

    res = verify_plan(r.plan, t1, t2, mesh)
    print("\ninterpreter check: OK,", res.transferred_elems,
          "elements crossed the network")

    # Execute on real (host) devices through shard_map collectives.
    jmesh = jax_mesh_of(mesh)
    g = np.arange(144, dtype=np.float32).reshape(12, 12)
    fn, in_spec, out_spec = make_executor(r.plan, t1, t2, mesh, jmesh)
    x = jax.device_put(g, NamedSharding(jmesh, in_spec))
    y = jax.jit(fn, out_shardings=NamedSharding(jmesh, out_spec))(x)
    assert np.array_equal(np.asarray(y), g)
    shard0 = y.addressable_shards[0]
    print(f"jax execution    : OK on {len(jax.devices())} devices; device 0 "
          f"now holds a {shard0.data.shape} tile")


if __name__ == "__main__":
    main()
