"""End-to-end training driver: synthetic data -> AdamW -> checkpoints,
with straggler watchdog and optional int8 gradient compression.

Default preset is CPU-friendly; ``--preset 100m --steps 300`` is the
full-size run described in the task spec (same code path).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60] [--preset tiny]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "20m", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    from repro.data.pipeline import DataConfig
    from repro.models.config import ModelConfig
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, train

    presets = {
        "tiny": ModelConfig(name="tiny", family="dense", n_layers=2,
                            d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
                            vocab=512),
        "20m": ModelConfig(name="lm20m", family="dense", n_layers=6,
                           d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
                           vocab=8192),
        "100m": ModelConfig(name="lm100m", family="dense", n_layers=12,
                            d_model=768, n_heads=12, n_kv_heads=12,
                            d_ff=3072, vocab=32768),
    }
    cfg = presets[args.preset]
    data = DataConfig(global_batch=8, seq_len=128)
    tcfg = TrainConfig(steps=args.steps, microbatches=2,
                       ckpt_every=20, ckpt_dir=args.ckpt,
                       grad_compression=args.compress)

    def report(step, m):
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}"
                  f"  {m['step_time'] * 1e3:.0f}ms")

    res = train(cfg, tcfg, data,
                AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
                on_metrics=report)
    print(f"\ndone: {res.steps_run} steps, loss "
          f"{res.losses[0]:.3f} -> {res.losses[-1]:.3f}, "
          f"stragglers flagged: {len(res.stragglers)}")


if __name__ == "__main__":
    main()
