"""Elastic re-scaling demo: checkpoint a model trained under one sharding
policy, restore it under another — the layout change is planned by the
paper's synthesizer and EXECUTED with shard_map collectives on 16 (host)
devices, with the memory/transfer comparison against the XLA-style
fallback printed per leaf class.

Run:  PYTHONPATH=src python examples/elastic_reshard.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import numpy as np


def main():
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import Mesh as CMesh
    from repro.checkpoint.elastic import dist_type_of, reshard_plan
    from repro.core.api import plan_redistribution
    from repro.core.jax_exec import jax_mesh_of, make_executor

    # A mid-training re-scale: TP degree 4 -> 2, DP 4 -> 8 on 16 devices.
    mesh = CMesh.make({"data": 4, "model": 4})
    jmesh = jax_mesh_of(mesh)

    leaves = {
        "attn/wq": ((1024, 2048), P(None, "model"), P(None, ("model",))),
        "mlp/wi": ((1024, 4096), P(None, "model"), P("model", None)),
        "embed": ((32768, 1024), P(("data", "model"), None),
                  P("model", "data")),
    }
    print("re-scaling parameter layouts on a 4x4 mesh:\n")
    total_ours = total_xla = 0
    for name, (shape, old_spec, new_spec) in leaves.items():
        t1 = dist_type_of(shape, old_spec, mesh)
        t2 = dist_type_of(shape, new_spec, mesh)
        r = plan_redistribution(t1, t2, mesh)
        from repro.core import plan_xla
        b = plan_xla(t1, t2, mesh)
        print(f"  {name:10s} {str(t1):34s} -> {str(t2)}")
        print(f"             plan: {r.plan.describe()}")
        print(f"             cost {r.plan.cost():>9} vs XLA {b.cost():>9}  "
              f"peak {r.plan.height():>9} vs XLA {b.height():>9}")
        total_ours += r.plan.cost()
        total_xla += b.cost()

        # execute the first leaf end-to-end on devices
        if name == "attn/wq":
            g = np.arange(np.prod(shape), dtype=np.float32).reshape(shape)
            fn, in_spec, out_spec = make_executor(r.plan, t1, t2, mesh, jmesh)
            x = jax.device_put(g, NamedSharding(jmesh, in_spec))
            y = jax.jit(fn, out_shardings=NamedSharding(jmesh, out_spec))(x)
            assert np.array_equal(np.asarray(y), g)
            print("             executed on devices: OK")
    print(f"\ntotal transfer: ours {total_ours} vs XLA-style {total_xla} "
          f"elements/device "
          f"({total_xla / max(total_ours, 1):.1f}x saving)")


if __name__ == "__main__":
    main()
